//! The transport fault-injection suite — the coordinator's failure
//! semantics, pinned end to end (see `rust/src/coordinator/README.md`):
//!
//! 1. **Fidelity**: the zero-fault virtual fabric is *bit-identical* to
//!    the in-process channels — per-link FIFO order forces every
//!    float-op ordering, so losses match exactly, not approximately.
//! 2. **No hangs**: a crash-stopped stage (kill-switch), a panicking
//!    backend, or a 100 %-lossy link turns every driver collect loop
//!    (step, update, checkpoint) into a prompt `Err` with a progress
//!    diagnostic — never a parked `recv()`.
//! 3. **Observability**: the injected per-link latency is recoverable
//!    from the delivery metrics, and the wavefront model with comm
//!    edges (`stream_plan_per_stage_comm`) predicts the executed
//!    forward-sweep makespan under that injected latency.

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::Result;
use terapipe::backend::{BackendSpec, NativeBackend, NativeSpec, StageBackend};
use terapipe::coordinator::transport::{LinkCfg, LinkId, NetConfig};
use terapipe::coordinator::{
    InProcTransport, TimedPhase, TrainConfig, Trainer, Transport, VirtualTransport,
};
use terapipe::data::{synthetic_corpus, Batch, Batcher};
use terapipe::perfmodel::measure::Measurements;
use terapipe::perfmodel::{measure, CostModel};
use terapipe::runtime::manifest::ModelDims;
use terapipe::runtime::tensor::HostTensor;
use terapipe::sim::schedule::stream_plan_per_stage_comm;
use terapipe::sim::wavefront;

const GRAN: usize = 4;
const STAGES: usize = 2;

fn spec() -> NativeSpec {
    NativeSpec::new(
        ModelDims {
            vocab: 64,
            hidden: 32,
            num_heads: 4,
            layers_per_stage: 1,
            num_stages: STAGES,
            seq_len: 32,
            batch: 2,
            block_ctx: 8,
            seed: 9,
        },
        GRAN,
    )
}

fn batches_for(m: &ModelDims, n: usize) -> Vec<Vec<Batch>> {
    let corpus = synthetic_corpus(1 << 13, 7);
    let mut b = Batcher::new(&corpus, m.batch, m.seq_len, 17);
    (0..n).map(|_| vec![b.next_batch()]).collect()
}

fn median(mut v: Vec<f64>) -> f64 {
    assert!(!v.is_empty());
    v.sort_by(f64::total_cmp);
    v[v.len() / 2]
}

// ---------------------------------------------------------------------
// 1. Fidelity: InProc == zero-fault Virtual, bit for bit
// ---------------------------------------------------------------------

fn run_losses<T: Transport>(transport: &T) -> Vec<f64> {
    let cfg = TrainConfig {
        slicing: vec![8, 8, 8, 8],
        steps: 3,
        seed: 17,
        ..Default::default()
    };
    let mut t = Trainer::with_spec_transport(spec(), cfg, transport).unwrap();
    let m = t.model.clone();
    batches_for(&m, 3).iter().map(|b| t.step(b).unwrap().loss).collect()
}

#[test]
fn inproc_and_zero_fault_virtual_losses_are_bit_identical() {
    let direct = {
        // the default constructor — the direct-mpsc path every caller uses
        let cfg = TrainConfig {
            slicing: vec![8, 8, 8, 8],
            steps: 3,
            seed: 17,
            ..Default::default()
        };
        let mut t = Trainer::with_spec(spec(), cfg).unwrap();
        let m = t.model.clone();
        batches_for(&m, 3).iter().map(|b| t.step(b).unwrap().loss).collect::<Vec<f64>>()
    };
    let inproc = run_losses(&InProcTransport);
    let virt = run_losses(&VirtualTransport::new(NetConfig::default()));
    assert_eq!(direct, inproc, "explicit InProcTransport differs from the default path");
    assert_eq!(inproc, virt, "zero-fault virtual fabric is not bit-identical to mpsc");
}

// ---------------------------------------------------------------------
// 2. No hangs: crash-stop, panic and loss all fail promptly
// ---------------------------------------------------------------------

/// Crash-stop the last stage after it delivered `budget` messages, run
/// `steps` and then a checkpoint, and return the first error. With
/// slicing `[16, 16]` × 1 microbatch the last stage's delivery sequence
/// is Fwd, Fwd, Update, Checkpoint — so the budget picks which collect
/// loop observes the death.
fn first_error_with_budget(budget: u64) -> (String, Duration) {
    let net = NetConfig::seeded(0).with_kill_after(STAGES - 1, budget);
    let vt = VirtualTransport::new(net);
    let cfg = TrainConfig {
        slicing: vec![16, 16],
        steps: 1,
        seed: 17,
        recv_timeout_ms: Some(500),
        ..Default::default()
    };
    let mut t = Trainer::with_spec_transport(spec(), cfg, &vt).unwrap();
    let m = t.model.clone();
    let batches = batches_for(&m, 1);
    let t0 = Instant::now();
    let err = t.step(&batches[0]).err().or_else(|| {
        let dir =
            std::env::temp_dir().join(format!("terapipe-kill-{budget}-{}", std::process::id()));
        let e = t.save_checkpoint(&dir).err();
        let _ = std::fs::remove_dir_all(&dir);
        e
    });
    let elapsed = t0.elapsed();
    (format!("{:#}", err.expect("a killed stage must surface an error")), elapsed)
}

#[test]
fn killed_stage_fails_the_step_collect_loop_promptly() {
    // budget 1: dies between the two forward slices → the step loop can
    // never complete. Depending on the exact interleaving the driver sees
    // either its inactivity deadline or stage 0's Fatal (next hop gone).
    let (msg, elapsed) = first_error_with_budget(1);
    assert!(
        msg.contains("during step") || msg.contains("hung up") || msg.contains("failed"),
        "unexpected diagnostic: {msg}"
    );
    assert!(elapsed < Duration::from_secs(20), "not prompt: {elapsed:?} ({msg})");
}

#[test]
fn killed_stage_fails_the_update_collect_loop_promptly() {
    // budget 2: both forwards delivered (the step's losses and backward
    // acks complete), death lands on the update ack.
    let (msg, elapsed) = first_error_with_budget(2);
    assert!(msg.contains("update"), "unexpected diagnostic: {msg}");
    assert!(elapsed < Duration::from_secs(20), "not prompt: {elapsed:?} ({msg})");
}

#[test]
fn killed_stage_fails_the_checkpoint_collect_loop_promptly() {
    // budget 3: the whole step (incl. update) completes, death lands on
    // the checkpoint ack.
    let (msg, elapsed) = first_error_with_budget(3);
    assert!(msg.contains("checkpoint"), "unexpected diagnostic: {msg}");
    assert!(elapsed < Duration::from_secs(20), "not prompt: {elapsed:?} ({msg})");
}

#[test]
fn fully_lossy_forward_link_times_out_with_progress_diagnostic() {
    // Silent drops disconnect nothing, so this is the pure-deadline path:
    // the only way the driver can fail is its inactivity timeout.
    let net = NetConfig::seeded(3)
        .with_link(LinkId::Fwd(0), LinkCfg { drop_prob: 1.0, ..Default::default() });
    let vt = VirtualTransport::new(net);
    let cfg = TrainConfig {
        slicing: vec![16, 16],
        steps: 1,
        seed: 17,
        recv_timeout_ms: Some(400),
        ..Default::default()
    };
    let mut t = Trainer::with_spec_transport(spec(), cfg, &vt).unwrap();
    let m = t.model.clone();
    let batches = batches_for(&m, 1);
    let t0 = Instant::now();
    let msg = format!("{:#}", t.step(&batches[0]).unwrap_err());
    assert!(msg.contains("during step"), "unexpected diagnostic: {msg}");
    assert!(msg.contains("losses"), "diagnostic should carry progress: {msg}");
    assert!(t0.elapsed() < Duration::from_secs(20), "not prompt: {:?}", t0.elapsed());
    drop(t);
    let metrics = vt.link_metrics(LinkId::Fwd(0));
    assert_eq!(metrics.sent, 0, "nothing should survive a drop_prob=1 link");
    assert!(metrics.dropped >= 2, "both activations should be metered as dropped");
}

// A backend wrapper that panics in `stage_fwd` on one chosen stage —
// the in-worker failure mode that used to hang the driver forever.
#[derive(Clone)]
struct PanicSpec {
    inner: NativeSpec,
    panic_stage: usize,
}

struct PanicBackend {
    inner: NativeBackend,
    armed: bool,
}

impl BackendSpec for PanicSpec {
    type Backend = PanicBackend;

    fn model(&self) -> ModelDims {
        self.inner.model()
    }

    fn buckets(&self) -> Vec<usize> {
        self.inner.buckets()
    }

    fn build(
        &self,
        stage: usize,
        num_stages: usize,
        resume: Option<&Path>,
    ) -> Result<PanicBackend> {
        Ok(PanicBackend {
            inner: self.inner.build(stage, num_stages, resume)?,
            armed: stage == self.panic_stage,
        })
    }
}

impl StageBackend for PanicBackend {
    fn dims(&self) -> &ModelDims {
        self.inner.dims()
    }

    fn embed_fwd(&mut self, tokens: &[i32], len: usize, off: usize) -> Result<HostTensor> {
        self.inner.embed_fwd(tokens, len, off)
    }

    fn stage_fwd(
        &mut self,
        h: &HostTensor,
        k_ctx: &HostTensor,
        v_ctx: &HostTensor,
        off: usize,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        if self.armed {
            panic!("injected fault: stage compute blew up");
        }
        self.inner.stage_fwd(h, k_ctx, v_ctx, off)
    }

    fn head_loss(&mut self, h_out: &HostTensor, targets: &[i32], len: usize) -> Result<f32> {
        self.inner.head_loss(h_out, targets, len)
    }

    fn head_bwd(&mut self, h_out: &HostTensor, targets: &[i32], len: usize) -> Result<HostTensor> {
        self.inner.head_bwd(h_out, targets, len)
    }

    #[allow(clippy::too_many_arguments)]
    fn stage_bwd(
        &mut self,
        h_in: &HostTensor,
        k_ctx: &HostTensor,
        v_ctx: &HostTensor,
        off: usize,
        g_h: &HostTensor,
        g_know: &HostTensor,
        g_vnow: &HostTensor,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        self.inner.stage_bwd(h_in, k_ctx, v_ctx, off, g_h, g_know, g_vnow)
    }

    fn embed_bwd(
        &mut self,
        tokens: &[i32],
        len: usize,
        off: usize,
        g_h: &HostTensor,
    ) -> Result<()> {
        self.inner.embed_bwd(tokens, len, off, g_h)
    }

    fn update(&mut self, step: i32, lr: f32) -> Result<()> {
        self.inner.update(step, lr)
    }

    fn checkpoint(&self, dir: &Path) -> Result<()> {
        self.inner.checkpoint(dir)
    }
}

#[test]
fn worker_panic_mid_step_surfaces_as_prompt_error_not_hang() {
    let cfg = TrainConfig {
        slicing: vec![16, 16],
        steps: 1,
        seed: 17,
        recv_timeout_ms: Some(60_000), // must NOT be what saves us
        ..Default::default()
    };
    let pspec = PanicSpec { inner: spec(), panic_stage: 1 };
    let mut t = Trainer::with_spec(pspec, cfg).unwrap();
    let m = t.model.clone();
    let batches = batches_for(&m, 1);
    let t0 = Instant::now();
    let msg = format!("{:#}", t.step(&batches[0]).unwrap_err());
    assert!(msg.contains("panicked"), "panic should surface in the error: {msg}");
    assert!(msg.contains("injected fault"), "panic payload should survive: {msg}");
    // Fatal travels as a message, so this fails in milliseconds — far
    // inside the 60 s deadline, proving catch_unwind (not the timeout)
    // reported it.
    assert!(t0.elapsed() < Duration::from_secs(10), "not prompt: {:?}", t0.elapsed());
}

// ---------------------------------------------------------------------
// 3. Observability: injected latency is recoverable and predictive
// ---------------------------------------------------------------------

const INJECT_MS: f64 = 12.0;

#[test]
fn fitted_comm_recovers_injected_latency_and_predicts_makespan() {
    let strict = std::env::var("TERAPIPE_EXEC_STRICT").is_ok();
    let tol = if strict { 0.20 } else { 0.35 };
    let slicings: [&[usize]; 3] = [&[8, 8, 8, 8], &[16, 16], &[4, 4, 8, 16]];
    let steps = 5;

    // ---- execute under injected Fwd(0) latency, pooling compute
    // samples and comm deliveries across slicings ----
    let mut all: Vec<HashMap<(u32, u32), Vec<f64>>> = vec![HashMap::new(); STAGES];
    let mut executed: Vec<f64> = Vec::new();
    let mut delay_by_len: HashMap<usize, Vec<f64>> = HashMap::new();
    for sl in slicings {
        let net =
            NetConfig::seeded(29).with_link(LinkId::Fwd(0), LinkCfg::with_latency(INJECT_MS));
        let vt = VirtualTransport::new(net);
        let cfg = TrainConfig {
            slicing: sl.to_vec(),
            steps,
            trace: true,
            seed: 17,
            ..Default::default()
        };
        let mut t = Trainer::with_spec_transport(spec(), cfg, &vt).unwrap();
        let m = t.model.clone();
        let corpus = synthetic_corpus(1 << 13, 7);
        let mut batcher = Batcher::new(&corpus, m.batch, m.seq_len, 17);
        let mut makespans = Vec::new();
        for step in 0..steps {
            let batches: Vec<_> = (0..1).map(|_| batcher.next_batch()).collect();
            let fwd_ms = t.step(&batches).unwrap().fwd_ms;
            if step == 0 {
                continue; // warmup: cold caches, lazy thread spin-up
            }
            makespans.push(fwd_ms);
            for s in t.last_timings() {
                if s.phase == TimedPhase::Fwd {
                    all[s.stage].entry((s.len as u32, s.off as u32)).or_default().push(s.ms);
                }
            }
        }
        executed.push(median(makespans));
        drop(t);
        for d in &vt.link_metrics(LinkId::Fwd(0)).deliveries {
            if let Some(len) = d.len {
                delay_by_len.entry(len).or_default().push(d.delay_ms);
            }
        }
    }

    // ---- the metered deliveries recover the injected latency ----
    assert!(!delay_by_len.is_empty(), "no activations crossed the instrumented link");
    let mut hop_est: HashMap<usize, f64> = HashMap::new();
    for (&len, v) in &delay_by_len {
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        let rel = (mean - INJECT_MS).abs() / INJECT_MS;
        assert!(
            rel < 0.15,
            "len {len}: fitted comm {mean:.3} ms vs injected {INJECT_MS} ms (rel {rel:.3})"
        );
        hop_est.insert(len, mean);
    }

    // ---- per-stage measure → fit on the compute samples (comm rides
    // the plan's cross-stage edges, not the durations) ----
    let mut fits = Vec::with_capacity(STAGES);
    for stage_samples in &all {
        let mut base = Vec::new();
        let mut ctx_samples = Vec::new();
        for (&(i, j), v) in stage_samples {
            let ms = median(v.clone());
            if j == 0 {
                base.push((i, ms));
            } else {
                ctx_samples.push((i, j, ms));
            }
        }
        assert!(base.len() >= 3, "base curve too thin: {base:?}");
        assert!(ctx_samples.len() >= 4, "ctx samples too thin: {ctx_samples:?}");
        let meas = Measurements {
            granularity: GRAN as u32,
            base,
            ctx_samples,
            repeats: (steps - 1) as u32,
        };
        fits.push(measure::fit(&meas, spec().model.seq_len as u32).unwrap());
    }

    // ---- wavefront with comm edges predicts the executed makespan ----
    for (sl, exec_ms) in slicings.iter().zip(&executed) {
        let mut durs: Vec<Vec<f64>> = Vec::with_capacity(STAGES);
        for fitted in &fits {
            let mut stage_durs = Vec::with_capacity(sl.len());
            let mut off = 0u32;
            for &len in sl.iter() {
                stage_durs.push(fitted.t(len as u32, off));
                off += len as u32;
            }
            durs.push(stage_durs);
        }
        let hop: Vec<f64> = sl.iter().map(|len| hop_est[len]).collect();
        let plan = stream_plan_per_stage_comm(&durs, &[hop]);
        assert!(wavefront::is_regular(&plan), "comm stream plan must be regular");
        let predicted = wavefront::evaluate(&plan, false).unwrap().makespan_ms;
        assert!(predicted > INJECT_MS, "prediction must include the injected hop");
        let rel = (predicted - exec_ms).abs() / exec_ms;
        assert!(
            rel < tol,
            "slicing {sl:?}: wavefront predicts {predicted:.3} ms, executed {exec_ms:.3} ms \
             (rel {rel:.2} ≥ {tol})"
        );
    }
}
