//! Engine-equivalence property suite: the arena-backed discrete-event
//! core must be *bit-identical* to the retained reference engine
//! (`simulate_ref`) on arbitrary plans — barriers, memory caps, edge
//! delays, priority ties, deadlocks — and the closed-form wavefront
//! evaluator must agree with the DES within 1e-9 on the regular plan
//! class it accepts (in practice the two are bit-identical too: they
//! perform the same `max`/`+` operations).
//!
//! Determinism note: the only heap-order freedom between the two DES
//! implementations is among equal-time wake events on *different* stages,
//! which commute (a dispatch touches only its own stage), and all
//! generated durations are strictly positive so the final
//! (stage, start)-sorted traces are unique.

use terapipe::sim::engine::{simulate, simulate_many, simulate_ref, SimArena};
use terapipe::sim::schedule::{build_plan, PhaseCost};
use terapipe::sim::wavefront;
use terapipe::sim::{Item, Phase, Plan, SimResult};
use terapipe::solver::{JointScheme, SliceScheme};
use terapipe::util::prop;

/// Randomized plan over the simulator's full feature set. Dependencies
/// always point to lower ids (no cycles — deadlock still arises from
/// barrier × memory-cap interactions); deps are distinct (the reference
/// engine's delay lookup collapses duplicate edges to the first match,
/// which no real builder emits). Priorities are drawn from a small range
/// so ties are common; ids break them.
fn random_dag_plan(g: &mut prop::Gen) -> Plan {
    let k = g.int(1, 5) as usize;
    let parts = g.int(1, 3) as usize;
    let n = g.int(2, 40) as usize;
    let mut items = Vec::with_capacity(n);
    for id in 0..n {
        let stage = g.int(0, k as u32 - 1) as usize;
        let phase = if g.bool() { Phase::Fwd } else { Phase::Bwd };
        let part = g.int(0, parts as u32 - 1) as usize;
        let dur = g.float(0.01, 3.0);
        let mut deps: Vec<(usize, f64)> = Vec::new();
        if id > 0 {
            let want = g.int(0, 3).min(id as u32);
            for _ in 0..want {
                let d = g.int(0, id as u32 - 1) as usize;
                if !deps.iter().any(|&(e, _)| e == d) {
                    let delay = if g.bool() { 0.0 } else { g.float(0.0, 1.0) };
                    deps.push((d, delay));
                }
            }
        }
        items.push(Item {
            id,
            stage,
            phase,
            part,
            slice: id,
            dur_ms: dur,
            deps,
            priority: g.int(0, 7) as u64,
        });
    }
    let mem_cap_parts = if g.bool() { Some(g.int(1, parts as u32)) } else { None };
    let flush_barrier = g.bool();
    Plan { stages: k, items, mem_cap_parts, flush_barrier }
}

/// Random plan in the wavefront's regular class: per-stage chains plus
/// random cross-stage and long-range edges (all to lower ids, all with
/// non-negative delays), built as interleaved per-stage streams.
fn random_regular_plan(g: &mut prop::Gen) -> Plan {
    let k = g.int(1, 6) as usize;
    let m = g.int(1, 24) as usize; // items per stage
    let n = k * m;
    let mut items = Vec::with_capacity(n);
    // id = i * k + s: stage-interleaved, so cross-stage deps at lower ids
    // exist for s > 0 at the same position i
    let mut last_on_stage = vec![usize::MAX; k];
    for id in 0..n {
        let s = id % k;
        let i = id / k;
        let mut deps = Vec::new();
        if last_on_stage[s] != usize::MAX {
            // the chain edge (sometimes with a delay on it)
            let delay = if g.bool() { 0.0 } else { g.float(0.0, 0.5) };
            deps.push((last_on_stage[s], delay));
        }
        if s > 0 {
            // cross-stage wavefront edge from (i, s-1)
            deps.push((i * k + s - 1, g.float(0.0, 0.8)));
        }
        if id > 0 && g.int(0, 4) == 0 {
            // occasional long-range extra edge
            let d = g.int(0, id as u32 - 1) as usize;
            if !deps.iter().any(|&(e, _)| e == d) {
                deps.push((d, g.float(0.0, 2.0)));
            }
        }
        items.push(Item {
            id,
            stage: s,
            phase: Phase::Fwd,
            part: 0,
            slice: i,
            dur_ms: g.float(0.01, 2.0),
            deps,
            priority: g.int(0, 3) as u64,
        });
        last_on_stage[s] = id;
    }
    Plan { stages: k, items, mem_cap_parts: None, flush_barrier: false }
}

fn assert_bit_identical(a: &SimResult, b: &SimResult, case: u64) {
    assert_eq!(
        a.makespan_ms.to_bits(),
        b.makespan_ms.to_bits(),
        "case {case}: makespan {} vs {}",
        a.makespan_ms,
        b.makespan_ms
    );
    assert_eq!(a.busy_ms.len(), b.busy_ms.len(), "case {case}");
    for (x, y) in a.busy_ms.iter().zip(&b.busy_ms) {
        assert_eq!(x.to_bits(), y.to_bits(), "case {case}: busy {x} vs {y}");
    }
    assert_eq!(
        a.bubble_fraction.to_bits(),
        b.bubble_fraction.to_bits(),
        "case {case}: bubble"
    );
    assert_eq!(a.trace.len(), b.trace.len(), "case {case}: trace length");
    for (x, y) in a.trace.iter().zip(&b.trace) {
        assert_eq!(x.stage, y.stage, "case {case}");
        assert_eq!(x.start_ms.to_bits(), y.start_ms.to_bits(), "case {case}: span start");
        assert_eq!(x.end_ms.to_bits(), y.end_ms.to_bits(), "case {case}: span end");
        assert_eq!(x.phase, y.phase, "case {case}");
        assert_eq!(x.part, y.part, "case {case}");
        assert_eq!(x.slice, y.slice, "case {case}");
    }
}

/// (a) Arena DES vs reference on random full-feature DAGs: bit-identical
/// results, including agreement on deadlock.
#[test]
fn prop_arena_des_is_bit_identical_to_reference() {
    let mut arena = SimArena::new();
    prop::run_cases(200, |g| {
        let plan = random_dag_plan(g);
        let r = simulate_ref(&plan);
        let a = arena.simulate_des(&plan, true);
        match (r, a) {
            (Ok(r), Ok(a)) => assert_bit_identical(&r, &a, g.case),
            (Err(re), Err(ae)) => {
                assert_eq!(re, ae, "case {}: deadlock reports differ", g.case)
            }
            (r, a) => panic!(
                "case {}: engines disagree on feasibility: ref {:?} vs arena {:?}",
                g.case,
                r.map(|x| x.makespan_ms),
                a.map(|x| x.makespan_ms)
            ),
        }
    });
}

/// (b) The auto-selecting entry point agrees with the oracle on the same
/// random DAGs (whichever engine the probe picked), and no-trace mode
/// changes no numbers.
#[test]
fn prop_auto_path_matches_reference() {
    let mut arena = SimArena::new();
    prop::run_cases(120, |g| {
        let plan = random_dag_plan(g);
        let r = simulate_ref(&plan);
        let a = simulate(&plan);
        match (r, a) {
            (Ok(r), Ok(a)) => {
                assert_eq!(r.makespan_ms.to_bits(), a.makespan_ms.to_bits(), "case {}", g.case);
                let nt = arena.simulate(&plan, false).unwrap();
                assert_eq!(r.makespan_ms.to_bits(), nt.makespan_ms.to_bits(), "case {}", g.case);
                assert!(nt.trace.is_empty(), "case {}", g.case);
                assert_eq!(r.busy_ms, nt.busy_ms, "case {}", g.case);
            }
            (Err(_), Err(_)) => {}
            (r, a) => panic!(
                "case {}: auto path disagrees on feasibility: ref {:?} vs auto {:?}",
                g.case,
                r.map(|x| x.makespan_ms),
                a.map(|x| x.makespan_ms)
            ),
        }
    });
}

/// (c) Wavefront vs DES on the regular class: the probe must accept, and
/// the closed form must agree within 1e-9 (with identical busy vectors
/// and trace shapes).
#[test]
fn prop_wavefront_matches_des_on_regular_plans() {
    let mut arena = SimArena::new();
    prop::run_cases(200, |g| {
        let plan = random_regular_plan(g);
        assert!(wavefront::is_regular(&plan), "case {}: generator emitted irregular plan", g.case);
        let wf = wavefront::evaluate(&plan, true).unwrap();
        let des = arena.simulate_des(&plan, true).unwrap();
        assert!(
            (wf.makespan_ms - des.makespan_ms).abs() < 1e-9,
            "case {}: wavefront {} vs DES {}",
            g.case,
            wf.makespan_ms,
            des.makespan_ms
        );
        for (s, (x, y)) in wf.busy_ms.iter().zip(&des.busy_ms).enumerate() {
            assert!((x - y).abs() < 1e-9, "case {}: stage {s} busy {x} vs {y}", g.case);
        }
        assert_eq!(wf.trace.len(), des.trace.len(), "case {}", g.case);
        for (x, y) in wf.trace.iter().zip(&des.trace) {
            assert_eq!(x.stage, y.stage, "case {}", g.case);
            assert!((x.start_ms - y.start_ms).abs() < 1e-9, "case {}", g.case);
            assert!((x.end_ms - y.end_ms).abs() < 1e-9, "case {}", g.case);
        }
        // the reference agrees too
        let r = simulate_ref(&plan).unwrap();
        assert!((wf.makespan_ms - r.makespan_ms).abs() < 1e-9, "case {}", g.case);
    });
}

/// (d) Plan-shape probe negative cases: irregular plans must route to the
/// DES. A fwd+bwd schedule from the real builder is irregular (its
/// backward chains run in reverse id order), and the auto path still
/// produces oracle-identical results on it.
#[test]
fn probe_rejects_irregular_plans_and_des_handles_them() {
    struct Const;
    impl PhaseCost for Const {
        fn fwd_ms(&self, _b: u32, _i: u32, _j: u32) -> f64 {
            1.0
        }
        fn bwd_ms(&self, _b: u32, _i: u32, _j: u32) -> f64 {
            2.0
        }
        fn comm_ms(&self, _b: u32, _i: u32) -> f64 {
            0.25
        }
    }
    let scheme = JointScheme {
        parts: vec![
            (
                1u32,
                SliceScheme { lens: vec![8, 8], total_ms: 0.0, t_max_ms: 0.0, latency_ms: 0.0 },
            ),
            (
                1u32,
                SliceScheme { lens: vec![16], total_ms: 0.0, t_max_ms: 0.0, latency_ms: 0.0 },
            ),
        ],
        latency_ms: 0.0,
    };
    for (cap, barrier) in [(None, false), (None, true), (Some(1), false)] {
        let plan = build_plan(&Const, &scheme, 3, cap, barrier);
        assert!(
            !wavefront::is_regular(&plan),
            "fwd+bwd schedule (cap {cap:?}, barrier {barrier}) must not probe regular"
        );
        let r = simulate_ref(&plan).unwrap();
        let a = simulate(&plan).unwrap();
        // constant costs make cross-stage finish times coincide exactly;
        // at such tie instants the reference may dispatch a stage while
        // its own same-instant completion is still queued, so *which*
        // equal-priority-class item runs can differ — for these schedules
        // the aggregates are exactly equal (the randomized suite above,
        // with continuous durations and hence no ties, pins full trace
        // bit-identity)
        assert_eq!(r.makespan_ms.to_bits(), a.makespan_ms.to_bits(), "cap {cap:?} barrier {barrier}");
        assert_eq!(r.busy_ms, a.busy_ms, "cap {cap:?} barrier {barrier}");
        assert_eq!(r.bubble_fraction.to_bits(), a.bubble_fraction.to_bits());
        assert_eq!(r.trace.len(), a.trace.len());
    }
}

/// (e) Batched replay equals per-plan replay, in order, across a mixed
/// bag of regular and irregular plans.
#[test]
fn prop_simulate_many_matches_per_plan_results() {
    let mut plans = Vec::new();
    prop::run_cases(40, |g| {
        plans.push(if g.bool() { random_dag_plan(g) } else { random_regular_plan(g) });
    });
    let batched = simulate_many(&plans, false);
    assert_eq!(batched.len(), plans.len());
    for (i, (p, b)) in plans.iter().zip(&batched).enumerate() {
        match (simulate(p), b) {
            (Ok(single), Ok(b)) => {
                assert_eq!(
                    single.makespan_ms.to_bits(),
                    b.makespan_ms.to_bits(),
                    "plan {i}: batched diverges from single"
                );
                assert!(b.trace.is_empty(), "plan {i}: no-trace batch returned spans");
            }
            (Err(se), Err(be)) => assert_eq!(&se, be, "plan {i}"),
            (s, b) => panic!("plan {i}: feasibility disagreement: {s:?} vs {b:?}"),
        }
    }
}
