//! Equivalence properties for the joint §3.4 solver on the shared engine.
//!
//! `solve_joint_exact` now runs on the same generic enumeration engine as
//! the §3.3 token solver (feasibility binary search + blocked parallel
//! scan with a shared atomic pruning bound), with parallel table builds
//! (`TableCostModel::build_par`) and parallel per-b DPs underneath. The
//! search is deterministic with ties broken by candidate order, so the
//! parallel solver must return **bit-identical** plans to the retained
//! sequential oracle `solve_joint_seq` (serial builds, serial DPs, plain
//! ascending scan) — not "close", identical, across sequence lengths,
//! pipeline depths, batch sizes, microbatch caps, ε values, and model
//! shapes. Mirrors `solver_parallel_equivalence.rs` for the token solver.

use terapipe::config::presets;
use terapipe::perfmodel::analytic::AnalyticModel;
use terapipe::perfmodel::CostModel;
use terapipe::solver::joint::{solve_joint, solve_joint_exact, solve_joint_seq, JointOpts};
use terapipe::util::prop;

/// Random affine-with-context cost model whose terms scale with the
/// microbatch size `b` — compute roughly linearly (with a sublinear knee
/// factor), comm linearly — so the batch composition is a real trade-off.
#[derive(Clone)]
struct RandJointModel {
    over: f64,
    lin: f64,
    ctx: f64,
    comm: f64,
    /// Marginal cost of one extra sequence in the microbatch (0 = free
    /// batching ⇒ one big part; 1 = linear ⇒ indifferent).
    scale: f64,
    b: u32,
}

impl CostModel for RandJointModel {
    fn t(&self, i: u32, j: u32) -> f64 {
        let f = 1.0 + self.scale * (self.b as f64 - 1.0);
        f * (self.over + self.lin * i as f64 + self.ctx * i as f64 * j as f64)
    }
    fn t_comm(&self, _i: u32) -> f64 {
        self.comm * self.b as f64
    }
}

struct Cfg {
    over: f64,
    lin: f64,
    ctx: f64,
    comm: f64,
    scale: f64,
}

fn random_cfg(g: &mut prop::Gen) -> Cfg {
    Cfg {
        over: g.float(0.01, 2.0),
        lin: g.float(0.001, 0.1),
        ctx: g.float(0.0, 3e-4),
        comm: g.float(0.0, 0.3),
        scale: g.float(0.1, 1.2),
    }
}

fn assert_joint_identical(
    par: &terapipe::solver::JointScheme,
    seq: &terapipe::solver::JointScheme,
    label: &str,
) {
    assert_eq!(par.parts.len(), seq.parts.len(), "{label}: part count");
    for (i, ((pb, ps), (sb, ss))) in par.parts.iter().zip(&seq.parts).enumerate() {
        assert_eq!(pb, sb, "{label}: part {i} batch size");
        assert_eq!(ps.lens, ss.lens, "{label}: part {i} scheme");
        assert!(
            ps.total_ms == ss.total_ms && ps.t_max_ms == ss.t_max_ms,
            "{label}: part {i} non-bit-identical floats: {ps:?} vs {ss:?}"
        );
    }
    assert!(
        par.latency_ms == seq.latency_ms,
        "{label}: latency {} vs {}",
        par.latency_ms,
        seq.latency_ms
    );
}

/// (a) Randomized (L, K, batch, b_max, ε, cost-model) configs: the engine
/// path is bit-identical to the sequential oracle — plans, per-part
/// `total_ms`/`t_max_ms`, and total latency all compare with `==`.
#[test]
fn prop_joint_exact_bit_identical_to_sequential_oracle() {
    prop::run_cases(100, |g| {
        let cfg = random_cfg(g);
        let gran = *g.choose(&[8u32, 16, 32]);
        let l = g.int(2, 12) * gran;
        let k = g.int(1, 16);
        let batch = g.int(1, 6);
        let b_cap = g.int(1, 4).min(batch);
        let eps = *g.choose(&[0.0f64, 0.1, 0.5]);
        let opts = JointOpts {
            granularity: gran,
            eps_ms: eps,
            max_microbatch: Some(b_cap),
        };
        let mk = |b: u32| RandJointModel {
            over: cfg.over,
            lin: cfg.lin,
            ctx: cfg.ctx,
            comm: cfg.comm,
            scale: cfg.scale,
            b,
        };
        let par = solve_joint_exact(&mk, batch, l, k, &opts);
        let seq = solve_joint_seq(&mk, batch, l, k, &opts);
        let label = format!(
            "case {} (L={l}, g={gran}, K={k}, B={batch}, b_max={b_cap}, eps={eps})",
            g.case
        );
        assert_joint_identical(&par, &seq, &label);
        assert_eq!(par.batch(), batch, "{label}: batch coverage");
    });
}

/// (b) The exact global-t_max search never loses to the paper's two-phase
/// reduction at ε = 0: every reduction plan is discoverable at its own
/// achieved budget, which sits in the exact solver's union pool.
#[test]
fn prop_joint_exact_never_worse_than_reduction() {
    prop::run_cases(20, |g| {
        let cfg = random_cfg(g);
        let gran = *g.choose(&[8u32, 16]);
        let l = g.int(2, 10) * gran;
        let k = g.int(2, 16);
        let batch = g.int(2, 6);
        let b_cap = g.int(1, 3).min(batch);
        let opts = JointOpts {
            granularity: gran,
            eps_ms: 0.0,
            max_microbatch: Some(b_cap),
        };
        let mk = |b: u32| RandJointModel {
            over: cfg.over,
            lin: cfg.lin,
            ctx: cfg.ctx,
            comm: cfg.comm,
            scale: cfg.scale,
            b,
        };
        let exact = solve_joint_exact(&mk, batch, l, k, &opts);
        let reduction = solve_joint(&mk, batch, l, k, &opts);
        assert!(
            exact.latency_ms <= reduction.latency_ms + 1e-6,
            "case {}: exact {} vs reduction {}",
            g.case,
            exact.latency_ms,
            reduction.latency_ms
        );
    });
}

/// Same bit-identity contract on the paper-scale analytic model (setting
/// (8): K = 48 — the configuration the joint bench times).
#[test]
fn paper_setting8_joint_parallel_matches_sequential() {
    let setting = presets::setting(8);
    let base = AnalyticModel::from_setting(&setting, 1);
    let l = setting.model.seq_len;
    let k = setting.parallel.pipeline_stages;
    for (gran, eps, batch, b_cap) in [(128u32, 0.1f64, 8u32, 4u32), (128, 0.0, 4, 2)] {
        let opts = JointOpts {
            granularity: gran,
            eps_ms: eps,
            max_microbatch: Some(b_cap),
        };
        let par = solve_joint_exact(|b| base.with_microbatch(b), batch, l, k, &opts);
        let seq = solve_joint_seq(|b| base.with_microbatch(b), batch, l, k, &opts);
        assert_joint_identical(&par, &seq, &format!("g={gran} eps={eps} B={batch}"));
    }
}
