//! Integration tests for the `obs` layer: span schema round-trip, a real
//! traced training run exported to Perfetto JSON and parsed back with
//! the repo's own parser, recorder determinism under rayon pools,
//! the planted-straggler span differential, and virtual-transport link
//! histograms flowing into the metrics snapshot.
//!
//! Only `perfetto_export_from_a_real_traced_run_parses_back` touches the
//! process-global recorder (tests share one process); everything else
//! uses private `Recorder` instances or synthesized spans, so parallel
//! test threads cannot pollute each other's streams.

use std::sync::Arc;

use terapipe::backend::NativeSpec;
use terapipe::coordinator::messages::Msg;
use terapipe::coordinator::transport::virt::{LinkCfg, NetConfig, VirtualTransport};
use terapipe::coordinator::transport::{LinkId, Transport};
use terapipe::coordinator::{TrainConfig, Trainer};
use terapipe::data::{synthetic_corpus, Batcher};
use terapipe::obs::export::{perfetto_trace, TraceBundle};
use terapipe::obs::{self, differential, metrics, Differential, Recorder, SpanKind, SpanRecord};
use terapipe::runtime::manifest::ModelDims;
use terapipe::sim::schedule::stream_plan_per_stage;
use terapipe::sim::{wavefront, Phase};
use terapipe::util::json::Json;

const STAGES: usize = 2;

fn spec() -> NativeSpec {
    NativeSpec::new(
        ModelDims {
            vocab: 64,
            hidden: 32,
            num_heads: 4,
            layers_per_stage: 1,
            num_stages: STAGES,
            seq_len: 32,
            batch: 2,
            block_ctx: 8,
            seed: 9,
        },
        4,
    )
}

#[test]
fn span_schema_round_trips_for_every_kind() {
    for (i, kind) in SpanKind::ALL.into_iter().enumerate() {
        let r = SpanRecord {
            kind,
            stage: if i % 2 == 0 { i as i32 } else { obs::DRIVER },
            mb: i as u32,
            slice: (i * 3) as u32,
            a: (i as u64) << 20,
            b: i as u64,
            start_us: 1_000_000 + i as u64,
            dur_us: (i * 17) as u64,
        };
        let text = r.to_json().to_string();
        let back = SpanRecord::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r, "schema round-trip failed for {kind:?}");
    }
}

/// The end-to-end path: real pipelined training with the global recorder
/// on, exported to Perfetto trace-event JSON, parsed back with the
/// repo's own parser and checked for structure and span coverage.
#[test]
fn perfetto_export_from_a_real_traced_run_parses_back() {
    obs::set_enabled(true);
    let cfg = TrainConfig {
        slicing: vec![8, 8, 8, 8],
        steps: 3,
        trace: true,
        seed: 11,
        ..Default::default()
    };
    let mut t = Trainer::with_spec(spec(), cfg).unwrap();
    let m = t.model.clone();
    let corpus = synthetic_corpus(1 << 13, 7);
    let mut batcher = Batcher::new(&corpus, m.batch, m.seq_len, 11);
    for _ in 0..3 {
        let batches: Vec<_> = (0..1).map(|_| batcher.next_batch()).collect();
        t.step(&batches).unwrap();
    }
    drop(t); // workers park and exit: the flush point is quiescent
    let flush = obs::flush();
    obs::set_enabled(false);

    // span coverage: every hot-path kind fired on the real run
    for kind in [
        SpanKind::SliceFwd,
        SpanKind::SliceBwd,
        SpanKind::KvRoute,
        SpanKind::AdamUpdate,
        SpanKind::Send,
        SpanKind::Recv,
    ] {
        assert!(
            flush.spans.iter().any(|s| s.kind == kind),
            "no {kind:?} span in a traced run"
        );
    }
    for stage in 0..STAGES as i32 {
        assert!(
            flush.spans.iter().any(|s| s.kind == SpanKind::SliceFwd && s.stage == stage),
            "stage {stage} recorded no forward slice"
        );
    }

    // predicted counterpart (uniform stand-in durations; structure is
    // what this test pins, the accuracy contract lives in
    // exec_sim_differential)
    let durs = vec![vec![1.0f64; 4]; STAGES];
    let predicted = wavefront::evaluate(&stream_plan_per_stage(&durs), true).unwrap().trace;
    let diff = Differential::from_spans(&flush.spans, &predicted);
    assert!(!diff.cells.is_empty());
    assert!(differential::measured_bubble_fraction(&flush.spans, STAGES).is_some());

    let bundle = TraceBundle {
        exec: flush.spans,
        predicted,
        stages: STAGES,
        dropped: flush.dropped,
    };
    let doc = perfetto_trace(&bundle).to_string();
    let parsed = Json::parse(&doc).expect("perfetto JSON must parse back");
    assert_eq!(parsed.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
    let evs = parsed.get("traceEvents").unwrap().as_arr().expect("traceEvents array");
    assert!(!evs.is_empty());
    for e in evs {
        assert!(e.get("ph").is_some(), "event without ph: {e:?}");
        assert!(e.get("pid").is_some() && e.get("tid").is_some());
    }
    // the same cell is string-identical on the exec and sim tracks
    let has = |pid: usize, name: &str| {
        evs.iter().any(|e| {
            e.get("pid").and_then(|p| p.as_usize()) == Some(pid)
                && e.get("name").and_then(|n| n.as_str()) == Some(name)
        })
    };
    assert!(has(0, "F0.0"), "exec track misses F0.0");
    assert!(has(2, "F0.0"), "sim track misses F0.0");
    assert!(
        evs.iter().any(|e| {
            e.get("pid").and_then(|p| p.as_usize()) == Some(1)
                && e.get("ph").and_then(|p| p.as_str()) == Some("i")
        }),
        "no send/recv instant on a link track"
    );
}

#[test]
fn recorder_is_deterministic_across_rayon_pool_sizes() {
    use rayon::prelude::*;
    let baseline: Vec<SpanRecord> = (0..500u64)
        .map(|i| SpanRecord {
            kind: if i % 2 == 0 { SpanKind::SliceFwd } else { SpanKind::SliceBwd },
            stage: (i % 4) as i32,
            mb: (i % 3) as u32,
            slice: (i % 5) as u32,
            a: i,
            b: i * 7,
            start_us: 1000 + (i * 37) % 211,
            dur_us: i % 13,
        })
        .collect();
    let mut streams: Vec<Vec<SpanRecord>> = Vec::new();
    for threads in [1usize, 2, 8] {
        let pool = rayon::ThreadPoolBuilder::new().num_threads(threads).build().unwrap();
        let rec = Arc::new(Recorder::new());
        rec.set_enabled(true);
        pool.install(|| {
            baseline.par_iter().for_each(|r| rec.record(*r));
        });
        let f = rec.flush();
        assert_eq!(f.dropped, 0, "pool of {threads} overflowed");
        assert_eq!(f.spans.len(), baseline.len(), "pool of {threads} lost spans");
        streams.push(f.spans);
    }
    assert_eq!(streams[0], streams[1], "1-thread and 2-thread flushes diverge");
    assert_eq!(streams[0], streams[2], "1-thread and 8-thread flushes diverge");
}

/// Pinned differential: the wavefront predicts a uniform pipeline, the
/// "executed" spans replay it with stage 2 running 4× slower — the
/// differential must name exactly that stage as the worst offender.
#[test]
fn planted_straggler_stage_is_named_worst_offender() {
    let stages = 4;
    let durs = vec![vec![1.0f64; 3]; stages];
    let predicted = wavefront::evaluate(&stream_plan_per_stage(&durs), true).unwrap().trace;
    assert_eq!(predicted.len(), stages * 3);
    let exec: Vec<SpanRecord> = predicted
        .iter()
        .map(|p| SpanRecord {
            kind: if p.phase == Phase::Fwd { SpanKind::SliceFwd } else { SpanKind::SliceBwd },
            stage: p.stage as i32,
            mb: 0,
            slice: p.slice as u32,
            a: 0,
            b: 0,
            start_us: (p.start_ms * 1000.0) as u64,
            dur_us: if p.stage == 2 { 4000 } else { 1000 },
        })
        .collect();
    let diff = Differential::from_spans(&exec, &predicted);
    let worst = diff.worst().expect("aligned cells");
    assert_eq!(worst.stage, 2, "straggler not named: {}", diff.report());
    assert!((worst.rel_err - 3.0).abs() < 1e-9);
    assert!(diff.report().contains("stage 2"));
    // the non-straggler cells agree perfectly
    assert!(diff
        .cells
        .iter()
        .filter(|c| c.stage != 2)
        .all(|c| c.rel_err < 1e-9));
}

/// Satellite: the virtual transport's per-link delivery telemetry —
/// previously reachable only from tests — renders as Prometheus link
/// counters and delay histograms.
#[test]
fn link_histograms_flow_into_the_metrics_snapshot() {
    let net = NetConfig::seeded(3).with_link(LinkId::Fwd(0), LinkCfg::with_latency(2.0));
    let vt = VirtualTransport::new(net);
    let mut fabric = vt.connect(2);
    let next = fabric.stages[0].next.take().unwrap();
    for _ in 0..4 {
        next.send(Msg::Shutdown).unwrap();
    }
    for _ in 0..4 {
        fabric.stages[1].inbox.recv().unwrap();
    }
    let mut reg = metrics::MetricsRegistry::new();
    metrics::link_metrics(&mut reg, &vt.all_metrics());
    assert_eq!(reg.get("terapipe_link_sent_total", &[("link", "s0->s1")]), Some(4.0));
    let text = reg.render();
    assert!(text.contains("terapipe_link_delay_ms_bucket{link=\"s0->s1\""), "{text}");
    assert!(text.contains("terapipe_link_delay_ms_count{link=\"s0->s1\"} 4"), "{text}");
    assert!(text.contains("# TYPE terapipe_link_delay_ms histogram"));
}
