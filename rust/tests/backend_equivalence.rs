//! Native-backend equivalence: the pipelined, token-sliced backward must
//! equal the unsliced single-pass backward **before** the optimizer — the
//! gradient-level statement of the paper's synchronous-training claim,
//! pinned on the same seeded weights with tight fp32 tolerance.
//!
//! Also here: finite-difference spot checks of the hand-written VJPs
//! (attention over the padded KV context, layernorm, GELU MLP, embedding,
//! cross-entropy head) and the Adam formula against an f64 reference.

use terapipe::backend::{BackendSpec, NativeBackend, NativeSpec, StageBackend};
use terapipe::runtime::manifest::ModelDims;
use terapipe::runtime::tensor::HostTensor;
use terapipe::util::Rng;

fn dims() -> ModelDims {
    ModelDims {
        vocab: 31,
        hidden: 16,
        num_heads: 2,
        layers_per_stage: 2,
        num_stages: 2,
        seq_len: 12,
        batch: 2,
        block_ctx: 4,
        seed: 7,
    }
}

fn spec() -> NativeSpec {
    NativeSpec::new(dims(), 2)
}

fn random_tokens(d: &ModelDims, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let n = d.batch * d.seq_len;
    let tokens: Vec<i32> = (0..n).map(|_| rng.below(d.vocab as u32) as i32).collect();
    let targets: Vec<i32> = (0..n).map(|_| rng.below(d.vocab as u32) as i32).collect();
    (tokens, targets)
}

/// Slice a `[B, L]`-flattened id vector to the `[B, s]` window at `off`.
fn slice_ids(d: &ModelDims, ids: &[i32], off: usize, len: usize) -> Vec<i32> {
    let mut out = Vec::with_capacity(d.batch * len);
    for b in 0..d.batch {
        let row = b * d.seq_len + off;
        out.extend_from_slice(&ids[row..row + len]);
    }
    out
}

/// Drive a K-stage pipeline of native backends through one full
/// fwd+bwd over `slicing` — the exact worker algorithm (KV scatter,
/// reverse-order backward, context-grad accumulation), single-threaded.
/// Returns (summed loss, the backends with accumulated grads).
fn run_sliced(slicing: &[usize]) -> (f32, Vec<NativeBackend>) {
    let d = dims();
    let k = d.num_stages;
    let sp = spec();
    let mut stages: Vec<NativeBackend> = (0..k).map(|s| sp.build(s, k, None).unwrap()).collect();
    let (tokens, targets) = random_tokens(&d, 99);

    struct St {
        k_ctx: HostTensor,
        v_ctx: HostTensor,
        g_kacc: HostTensor,
        g_vacc: HostTensor,
        h_in: Vec<HostTensor>,
        h_out: Vec<HostTensor>, // last stage only
    }
    let mut state: Vec<St> = (0..k)
        .map(|_| St {
            k_ctx: HostTensor::zeros_f32(&d.kv_shape()),
            v_ctx: HostTensor::zeros_f32(&d.kv_shape()),
            g_kacc: HostTensor::zeros_f32(&d.kv_shape()),
            g_vacc: HostTensor::zeros_f32(&d.kv_shape()),
            h_in: Vec::new(),
            h_out: Vec::new(),
        })
        .collect();

    let offs: Vec<usize> = slicing
        .iter()
        .scan(0usize, |acc, &l| {
            let o = *acc;
            *acc += l;
            Some(o)
        })
        .collect();

    // ---- forward: slices in order through all stages ----
    let mut loss = 0f32;
    for (&len, &off) in slicing.iter().zip(&offs) {
        let toks = slice_ids(&d, &tokens, off, len);
        let mut h = stages[0].embed_fwd(&toks, len, off).unwrap();
        for s in 0..k {
            let (h_out, k_new, v_new) = {
                let st = &state[s];
                stages[s].stage_fwd(&h, &st.k_ctx, &st.v_ctx, off).unwrap()
            };
            let st = &mut state[s];
            st.k_ctx.write_at_axis(2, off, &k_new);
            st.v_ctx.write_at_axis(2, off, &v_new);
            st.h_in.push(h);
            if s == k - 1 {
                let tg = slice_ids(&d, &targets, off, len);
                loss += stages[s].head_loss(&h_out, &tg, len).unwrap();
                st.h_out.push(h_out.clone());
            }
            h = h_out;
        }
    }

    // ---- backward: slices in reverse order through stages in reverse ----
    for (i, (&len, &off)) in slicing.iter().zip(&offs).enumerate().rev() {
        let tg = slice_ids(&d, &targets, off, len);
        let h_out = state[k - 1].h_out[i].clone();
        let mut g_h = stages[k - 1].head_bwd(&h_out, &tg, len).unwrap();
        for s in (0..k).rev() {
            let (g_h_in, g_kctx, g_vctx) = {
                let st = &state[s];
                let g_know = st.g_kacc.read_at_axis(2, off, len);
                let g_vnow = st.g_vacc.read_at_axis(2, off, len);
                stages[s]
                    .stage_bwd(&st.h_in[i], &st.k_ctx, &st.v_ctx, off, &g_h, &g_know, &g_vnow)
                    .unwrap()
            };
            let st = &mut state[s];
            st.g_kacc.add_assign(&g_kctx);
            st.g_vacc.add_assign(&g_vctx);
            g_h = g_h_in;
        }
        let toks = slice_ids(&d, &tokens, off, len);
        stages[0].embed_bwd(&toks, len, off, &g_h).unwrap();
    }
    (loss, stages)
}

fn max_abs_diff(a: &[HostTensor], b: &[HostTensor]) -> f32 {
    let mut m = 0f32;
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.shape, y.shape);
        for (u, v) in x.as_f32().iter().zip(y.as_f32()) {
            m = m.max((u - v).abs());
        }
    }
    m
}

/// Pipelined sliced backward == unsliced single-pass backward on the same
/// weights: every parameter gradient on every stage, tight tolerance.
#[test]
fn sliced_backward_matches_unsliced_oracle() {
    let (loss_a, oracle) = run_sliced(&[12]);
    for slicing in [vec![6usize, 4, 2], vec![4, 4, 4], vec![2; 6]] {
        let (loss_b, sliced) = run_sliced(&slicing);
        assert!(
            (loss_a - loss_b).abs() < 1e-3,
            "{slicing:?}: loss {loss_a} vs {loss_b}"
        );
        for s in 0..oracle.len() {
            let d = max_abs_diff(&oracle[s].stage_p.grads, &sliced[s].stage_p.grads);
            assert!(d < 2e-4, "{slicing:?}: stage {s} grad diff {d}");
        }
        let d = max_abs_diff(
            &oracle[0].embed_p.as_ref().unwrap().grads,
            &sliced[0].embed_p.as_ref().unwrap().grads,
        );
        assert!(d < 2e-4, "{slicing:?}: embed grad diff {d}");
        let k = oracle.len() - 1;
        let d = max_abs_diff(
            &oracle[k].head_p.as_ref().unwrap().grads,
            &sliced[k].head_p.as_ref().unwrap().grads,
        );
        assert!(d < 2e-4, "{slicing:?}: head grad diff {d}");
    }
}

/// Sliced forward composes to the unsliced forward (loss identical).
#[test]
fn sliced_forward_composes() {
    let (full, _) = run_sliced(&[12]);
    let (sliced, _) = run_sliced(&[2, 6, 4]);
    assert!((full - sliced).abs() < 1e-3, "{full} vs {sliced}");
}

// ---------------------------------------------------------------------------
// Finite-difference validation of the hand-written VJPs
// ---------------------------------------------------------------------------

/// Whole-cell loss on a single-stage pipeline (embed → stage → head, one
/// slice, empty context) — the scalar function the VJPs differentiate.
fn loss_of(be: &mut NativeBackend, tokens: &[i32], targets: &[i32]) -> f32 {
    let d = be.dims().clone();
    let l = d.seq_len;
    let h = be.embed_fwd(tokens, l, 0).unwrap();
    let kv = HostTensor::zeros_f32(&d.kv_shape());
    let (h_out, _, _) = be.stage_fwd(&h, &kv, &kv, 0).unwrap();
    be.head_loss(&h_out, targets, l).unwrap()
}

/// Full backward on the same cell, leaving grads in the param sets.
fn grads_of(be: &mut NativeBackend, tokens: &[i32], targets: &[i32]) {
    let d = be.dims().clone();
    let l = d.seq_len;
    let h = be.embed_fwd(tokens, l, 0).unwrap();
    let kv = HostTensor::zeros_f32(&d.kv_shape());
    let (h_out, _, _) = be.stage_fwd(&h, &kv, &kv, 0).unwrap();
    let g_h = be.head_bwd(&h_out, targets, l).unwrap();
    let zero_kv = HostTensor::zeros_f32(&d.kv_new_shape(l));
    let (g_h_in, _, _) = be
        .stage_bwd(&h, &kv, &kv, 0, &g_h, &zero_kv, &zero_kv)
        .unwrap();
    be.embed_bwd(tokens, l, 0, &g_h_in).unwrap();
}

/// Finite-difference validation of the hand-written VJPs, one
/// *directional derivative* per parameter group: perturb the whole group
/// along a random ±1 direction `u` and compare `(L(θ+εu) − L(θ−εu))/2ε`
/// against `⟨∇L, u⟩`. Directional FD aggregates over thousands of
/// coordinates, so the f32 rounding noise that plagues per-coordinate
/// checks washes out — 5 % relative tolerance is comfortable.
#[test]
fn analytic_gradients_match_finite_differences() {
    let d = ModelDims { num_stages: 1, layers_per_stage: 2, ..dims() };
    let sp = NativeSpec::new(d.clone(), 2);
    let mut be = sp.build(0, 1, None).unwrap();
    let (tokens, targets) = random_tokens(&d, 5);
    grads_of(&mut be, &tokens, &targets);

    let eps = 1e-3f32;
    for group in ["stage", "embed", "head"] {
        // random ±1 direction per tensor of the group + ⟨g, u⟩ in f64
        let (dirs, dd): (Vec<Vec<f32>>, f64) = {
            let set = match group {
                "stage" => &be.stage_p,
                "embed" => be.embed_p.as_ref().unwrap(),
                _ => be.head_p.as_ref().unwrap(),
            };
            let mut rng = Rng::new(0xD1F7 + group.len() as u64);
            let mut dd = 0f64;
            let mut dirs = Vec::new();
            for g in &set.grads {
                let u: Vec<f32> = g
                    .as_f32()
                    .iter()
                    .map(|_| if rng.below(2) == 0 { 1.0 } else { -1.0 })
                    .collect();
                dd += g
                    .as_f32()
                    .iter()
                    .zip(&u)
                    .map(|(&gv, &uv)| gv as f64 * uv as f64)
                    .sum::<f64>();
                dirs.push(u);
            }
            (dirs, dd)
        };
        let mut shift = |be: &mut NativeBackend, sign: f32| {
            let set = match group {
                "stage" => &mut be.stage_p,
                "embed" => be.embed_p.as_mut().unwrap(),
                _ => be.head_p.as_mut().unwrap(),
            };
            for (p, u) in set.params.iter_mut().zip(&dirs) {
                for (pv, &uv) in p.as_f32_mut().iter_mut().zip(u) {
                    *pv += sign * eps * uv;
                }
            }
        };
        shift(&mut be, 1.0);
        let lp = loss_of(&mut be, &tokens, &targets) as f64;
        shift(&mut be, -2.0);
        let lm = loss_of(&mut be, &tokens, &targets) as f64;
        shift(&mut be, 1.0); // restore
        let fd = (lp - lm) / (2.0 * eps as f64);
        assert!(dd.abs() > 0.1, "{group}: degenerate direction ⟨g,u⟩ = {dd}");
        let rel = ((fd - dd) / dd).abs();
        assert!(rel < 0.05, "{group}: analytic {dd} vs fd {fd} (rel {rel})");
    }
}

/// Adam against an f64 reference of model.py's formula.
#[test]
fn adam_step_matches_reference_formula() {
    let sp = spec();
    let mut be = sp.build(0, 2, None).unwrap();
    // plant a known gradient, remember the starting params
    let mut rng = Rng::new(77);
    for g in &mut be.stage_p.grads {
        for x in g.as_f32_mut() {
            *x = (rng.f64() - 0.5) as f32;
        }
    }
    let p0: Vec<Vec<f32>> = be.stage_p.params.iter().map(|t| t.as_f32().to_vec()).collect();
    let g0: Vec<Vec<f32>> = be.stage_p.grads.iter().map(|t| t.as_f32().to_vec()).collect();
    be.update(1, 1e-3).unwrap();
    let (b1, b2, eps, lr) = (0.9f64, 0.999f64, 1e-8f64, 1e-3f64);
    for (ti, p_new) in be.stage_p.params.iter().enumerate() {
        for (c, &pv) in p_new.as_f32().iter().enumerate() {
            let g = g0[ti][c] as f64;
            let m = (1.0 - b1) * g;
            let v = (1.0 - b2) * g * g;
            let mhat = m / (1.0 - b1);
            let vhat = v / (1.0 - b2);
            let want = p0[ti][c] as f64 - lr * mhat / (vhat.sqrt() + eps);
            assert!(
                (pv as f64 - want).abs() < 1e-6,
                "param[{ti}][{c}]: {pv} vs {want}"
            );
        }
    }
    // grads were zeroed for the next accumulation round
    assert_eq!(be.stage_p.grad_max_abs(), 0.0);
}

/// `update` advances parameters in the loss-decreasing direction.
#[test]
fn training_signal_flows_end_to_end() {
    let d = ModelDims { num_stages: 1, ..dims() };
    let sp = NativeSpec::new(d.clone(), 2);
    let mut be = sp.build(0, 1, None).unwrap();
    let (tokens, targets) = random_tokens(&d, 13);
    let l0 = loss_of(&mut be, &tokens, &targets);
    for step in 1..=8 {
        grads_of(&mut be, &tokens, &targets);
        be.update(step, 1e-2).unwrap();
    }
    let l1 = loss_of(&mut be, &tokens, &targets);
    assert!(l1 < l0 - 0.2, "loss did not drop on a memorizable batch: {l0} -> {l1}");
}
