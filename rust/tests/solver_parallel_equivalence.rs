//! Equivalence properties for the parallel solver engine.
//!
//! The §3.3/§3.4 enumeration now runs on a multi-threaded engine
//! (feasibility binary search + blocked parallel scan with a shared atomic
//! pruning bound). The DP itself is deterministic with ties broken by
//! candidate order, so the engine must return **bit-identical** schemes to
//! the retained sequential reference (`solve_tokens_seq`) — not "close",
//! identical, across granularities, ε values, pipeline depths, and model
//! shapes. These tests are the contract that keeps the parallel path
//! honest as it gets further optimized.

use terapipe::config::presets;
use terapipe::perfmodel::analytic::AnalyticModel;
use terapipe::perfmodel::{CostModel, TableCostModel};
use terapipe::solver::bucketed::solve_fixed_tmax_restricted;
use terapipe::solver::dp::{solve_fixed_tmax, solve_tokens, solve_tokens_seq};
use terapipe::util::prop;

/// Random affine-with-context cost model drawn per case (same family the
/// sim-vs-solver properties use).
#[derive(Clone)]
struct RandModel {
    over: f64,
    lin: f64,
    ctx: f64,
    comm: f64,
}
impl CostModel for RandModel {
    fn t(&self, i: u32, j: u32) -> f64 {
        self.over + self.lin * i as f64 + self.ctx * i as f64 * j as f64
    }
    fn t_comm(&self, _i: u32) -> f64 {
        self.comm
    }
}

fn random_model(g: &mut prop::Gen) -> RandModel {
    RandModel {
        over: g.float(0.01, 2.0),
        lin: g.float(0.001, 0.1),
        ctx: g.float(0.0, 3e-4),
        comm: g.float(0.0, 0.3),
    }
}

/// (a) The parallel solver's output is bit-identical to the sequential
/// reference across granularities and ε values — lens, total, t_max, and
/// latency all compare with `==`, no tolerance.
#[test]
fn prop_parallel_solver_bit_identical_to_sequential_reference() {
    prop::run_cases(100, |g| {
        let m = random_model(g);
        let gran = *g.choose(&[8u32, 16, 32, 64]);
        let l = g.int(2, 20) * gran;
        let k = g.int(1, 32);
        let eps = *g.choose(&[0.0f64, 0.01, 0.1, 0.5]);

        let (par, pstats) = solve_tokens(&m, l, k, gran, eps);
        let (seq, sstats) = solve_tokens_seq(&m, l, k, gran, eps);

        assert_eq!(par.lens, seq.lens, "case {} (g={gran}, K={k}, eps={eps})", g.case);
        assert!(
            par.total_ms == seq.total_ms
                && par.t_max_ms == seq.t_max_ms
                && par.latency_ms == seq.latency_ms,
            "case {}: non-bit-identical floats: {par:?} vs {seq:?}",
            g.case
        );
        // both paths see the same deduplicated candidate pool
        assert_eq!(pstats.candidates, sstats.candidates, "case {}", g.case);
        // the parallel path never pays more scan DPs than the reference
        // (it skips the infeasible prefix the reference walks through)
        assert!(pstats.dps_run <= sstats.dps_run, "case {}", g.case);
    });
}

/// Same contract on the paper-scale analytic model (setting (9): K = 96,
/// L = 2048 — the configuration the acceptance bench times).
#[test]
fn paper_setting9_parallel_matches_sequential() {
    let setting = presets::setting(9);
    let base = AnalyticModel::from_setting(&setting, 1);
    let l = setting.model.seq_len;
    let k = setting.parallel.pipeline_stages;
    for (gran, eps) in [(64u32, 0.1f64), (32, 0.1), (32, 0.0)] {
        let (par, _) = solve_tokens(&base, l, k, gran, eps);
        let (seq, _) = solve_tokens_seq(&base, l, k, gran, eps);
        assert_eq!(par.lens, seq.lens, "g={gran} eps={eps}");
        assert!(
            par.latency_ms == seq.latency_ms && par.t_max_ms == seq.t_max_ms,
            "g={gran} eps={eps}: {} vs {}",
            par.latency_ms,
            seq.latency_ms
        );
    }
}

/// (b) `bucketed::solve_fixed_tmax_restricted` collapses to
/// `dp::solve_fixed_tmax` when every grid multiple is allowed — same
/// scheme, same total, bit-identical (both iterate k ascending, so the
/// tie-breaks coincide too).
#[test]
fn prop_restricted_fixed_tmax_equals_unrestricted_when_all_multiples_allowed() {
    prop::run_cases(100, |g| {
        let m = random_model(g);
        let gran = *g.choose(&[8u32, 16, 32]);
        let l = g.int(2, 20) * gran;
        let table = TableCostModel::build(&m, l, gran);
        let n = table.units();
        let all: Vec<usize> = (1..=n).collect();

        // budgets spanning infeasible → generous
        let top = table.at(n, 0) + table.comm_at(n);
        for f in [0.1f64, 0.4, 0.7, 1.0, 1.5] {
            let tmax = top * f;
            let free = solve_fixed_tmax(&table, tmax);
            let restr = solve_fixed_tmax_restricted(&table, tmax, &all);
            match (free, restr) {
                (None, None) => {}
                (Some(a), Some(b)) => {
                    assert_eq!(a.lens_units, b.lens_units, "case {} f={f}", g.case);
                    assert!(a.total_ms == b.total_ms, "case {} f={f}", g.case);
                }
                (a, b) => panic!(
                    "feasibility disagreement at case {} f={f}: free={} restr={}",
                    g.case,
                    a.is_some(),
                    b.is_some()
                ),
            }
        }
    });
}
