//! Bench: the DP solver itself (§3.3 "the dynamic programming can finish
//! within a minute"). Times `solve_tokens` and the exact joint solver at
//! paper scale across granularities, and reports the ε-grid/pruning
//! statistics.

use std::time::Instant;

use terapipe::config::presets;
use terapipe::perfmodel::analytic::AnalyticModel;
use terapipe::solver::dp::solve_tokens;
use terapipe::solver::joint::{solve_joint_analytic, JointOpts};
use terapipe::util::Stats;

fn main() {
    println!("# DP solver runtime (paper budget: under one minute at L=2048)");
    let setting = presets::setting(9); // deepest pipeline: K=96
    let base = AnalyticModel::from_setting(&setting, 1);
    let l = setting.model.seq_len;
    let k = setting.parallel.pipeline_stages;

    println!("\n## single-sequence token DP, setting (9), K={k}, L={l}");
    println!("| granularity | eps (ms) | candidates | DPs run | slices | wall (ms, mean ± std of 5) |");
    for (g, eps) in [(64u32, 0.1f64), (32, 0.1), (16, 0.1), (8, 0.1), (8, 0.0)] {
        let mut wall = Vec::new();
        let mut last = None;
        for _ in 0..5 {
            let t0 = Instant::now();
            let r = solve_tokens(&base, l, k, g, eps);
            wall.push(t0.elapsed().as_secs_f64() * 1e3);
            last = Some(r);
        }
        let (scheme, stats) = last.unwrap();
        let s = Stats::from_samples(&wall);
        println!(
            "| {g} | {eps} | {} | {} | {} | {} |",
            stats.candidates,
            stats.dps_run,
            scheme.num_slices(),
            s.pm()
        );
    }

    println!("\n## exact joint batch+token DP (knapsack over Algorithm-1 totals)");
    println!("| setting | B/pipe | granularity | wall (ms) |");
    for id in [5u32, 8, 9] {
        let st = presets::setting(id);
        let b = AnalyticModel::from_setting(&st, 1);
        let opts = JointOpts {
            granularity: 16,
            eps_ms: 0.1,
            max_microbatch: Some(8),
        };
        let t0 = Instant::now();
        let j = solve_joint_analytic(&b, st.batch_per_pipeline(), st.model.seq_len, st.parallel.pipeline_stages, &opts);
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        println!(
            "| ({id}) | {} | 16 | {ms:.0} | -> {}",
            st.batch_per_pipeline(),
            &j.notation()[..j.notation().len().min(60)]
        );
        assert!(ms < 60_000.0, "paper budget exceeded");
    }
}
