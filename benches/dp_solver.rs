//! Bench: the DP solver itself (§3.3 "the dynamic programming can finish
//! within a minute"). Times the parallel engine vs the retained sequential
//! reference at paper scale across granularities, reports per-run stats
//! (not just the last run), and emits a machine-readable
//! `BENCH_dp_solver.json` at the workspace root so the perf trajectory is
//! tracked across PRs.
//!
//! Each granularity densifies its `TableCostModel` **once** and reuses it
//! across repetitions via `solve_tokens_table`, so the numbers time the
//! DP — table densification is timed separately and reported on its own.

use terapipe::config::presets;
use terapipe::perfmodel::analytic::AnalyticModel;
use terapipe::perfmodel::TableCostModel;
use terapipe::solver::dp::{solve_tokens_table, solve_tokens_table_seq};
use terapipe::solver::joint::{solve_joint_analytic, solve_joint_seq, JointOpts};
use terapipe::util::json::Json;
use terapipe::util::{time_ms, Stats};

const REPS: usize = 5;

fn main() {
    println!("# DP solver runtime (paper budget: under one minute at L=2048)");
    let setting = presets::setting(9); // deepest pipeline: K=96
    let base = AnalyticModel::from_setting(&setting, 1);
    let l = setting.model.seq_len;
    let k = setting.parallel.pipeline_stages;
    let threads = rayon::current_num_threads();
    println!("threads: {threads}");

    let mut rows: Vec<Json> = Vec::new();

    println!("\n## single-sequence token DP, setting (9), K={k}, L={l}");
    println!("| granularity | eps (ms) | densify (ms) | candidates | DPs run | probe DPs | slices | wall ms (mean ± std of {REPS}) | runs |");
    for (g, eps) in [(64u32, 0.1f64), (32, 0.1), (16, 0.1), (8, 0.1), (8, 0.0)] {
        // densify once — the repetitions time the DP, not the table build
        let (table, densify_ms) = time_ms(|| TableCostModel::build(&base, l, g));
        let mut wall = Vec::with_capacity(REPS);
        let mut last = None;
        for _ in 0..REPS {
            let (r, ms) = time_ms(|| solve_tokens_table(&table, k, eps));
            wall.push(ms);
            last = Some(r);
        }
        let (scheme, stats) = last.unwrap();
        let s = Stats::from_samples(&wall);
        let runs = wall
            .iter()
            .map(|w| format!("{w:.2}"))
            .collect::<Vec<_>>()
            .join("/");
        println!(
            "| {g} | {eps} | {densify_ms:.2} | {} | {} | {} | {} | {} | {runs} |",
            stats.candidates,
            stats.dps_run,
            stats.probe_dps,
            scheme.num_slices(),
            s.pm()
        );
        rows.push(Json::obj(vec![
            ("granularity", Json::Num(g as f64)),
            ("eps_ms", Json::Num(eps)),
            ("densify_ms", Json::Num(densify_ms)),
            ("candidates", Json::Num(stats.candidates as f64)),
            ("dps_run", Json::Num(stats.dps_run as f64)),
            ("probe_dps", Json::Num(stats.probe_dps as f64)),
            ("slices", Json::Num(scheme.num_slices() as f64)),
            ("wall_ms_mean", Json::Num(s.mean)),
            ("wall_ms_std", Json::Num(s.std)),
            ("wall_ms_min", Json::Num(s.min)),
            ("wall_ms_max", Json::Num(s.max)),
            (
                "wall_ms_runs",
                Json::arr(wall.iter().map(|&w| Json::Num(w)).collect()),
            ),
        ]));
    }

    // ---- acceptance setting: parallel engine vs sequential reference ----
    // Setting (9), g = 8, eps = 0.1 — the ISSUE's ≥4× criterion. Outputs
    // are bit-identical (enforced by the equivalence property tests; spot
    // re-checked here); only the wall clock may differ.
    println!("\n## parallel engine vs sequential reference (K={k}, L={l}, g=8, eps=0.1)");
    let (table, _) = time_ms(|| TableCostModel::build(&base, l, 8));
    let mut par_wall = Vec::with_capacity(REPS);
    let mut seq_wall = Vec::with_capacity(REPS);
    let mut par_scheme = None;
    let mut seq_scheme = None;
    for _ in 0..REPS {
        let (r, ms) = time_ms(|| solve_tokens_table(&table, k, 0.1));
        par_wall.push(ms);
        par_scheme = Some(r.0);
        let (r, ms) = time_ms(|| solve_tokens_table_seq(&table, k, 0.1));
        seq_wall.push(ms);
        seq_scheme = Some(r.0);
    }
    let (par_scheme, seq_scheme) = (par_scheme.unwrap(), seq_scheme.unwrap());
    assert_eq!(
        par_scheme.lens, seq_scheme.lens,
        "parallel and sequential schemes must be bit-identical"
    );
    let ps = Stats::from_samples(&par_wall);
    let ss = Stats::from_samples(&seq_wall);
    // min-over-reps is the steadiest speedup estimator on a shared box
    let speedup = ss.min / ps.min.max(1e-9);
    println!("sequential reference: {} ms (min {:.2})", ss.pm(), ss.min);
    println!("parallel engine:      {} ms (min {:.2})", ps.pm(), ps.min);
    println!("speedup: {speedup:.2}x on {threads} threads");
    // (the ≥4x acceptance assert runs at the very end, AFTER the JSON
    // report is written — a regression must still leave a record)

    // ---- serial vs parallel table densification (build_par) ----
    println!("\n## table densification: build vs build_par (setting (9), L={l})");
    println!("| granularity | build (ms) | build_par (ms) | speedup |");
    let mut densify_rows: Vec<Json> = Vec::new();
    for g in [64u32, 16, 8] {
        let mut ser = Vec::with_capacity(REPS);
        let mut par = Vec::with_capacity(REPS);
        for _ in 0..REPS {
            let (_, ms) = time_ms(|| TableCostModel::build(&base, l, g));
            ser.push(ms);
            let (_, ms) = time_ms(|| TableCostModel::build_par(&base, l, g));
            par.push(ms);
        }
        let ss = Stats::from_samples(&ser);
        let ps = Stats::from_samples(&par);
        let sp = ss.min / ps.min.max(1e-9);
        println!("| {g} | {:.2} | {:.2} | {sp:.2}x |", ss.min, ps.min);
        densify_rows.push(Json::obj(vec![
            ("granularity", Json::Num(g as f64)),
            ("build_ms_min", Json::Num(ss.min)),
            ("build_par_ms_min", Json::Num(ps.min)),
            ("speedup_min_over_min", Json::Num(sp)),
        ]));
    }

    println!("\n## exact joint batch+token DP (shared engine: global t_max enumeration)");
    println!("| setting | B/pipe | granularity | wall (ms) |");
    let mut joint_rows: Vec<Json> = Vec::new();
    for id in [5u32, 8, 9] {
        let st = presets::setting(id);
        let b = AnalyticModel::from_setting(&st, 1);
        let opts = JointOpts {
            granularity: 16,
            eps_ms: 0.1,
            max_microbatch: Some(8),
        };
        let (j, ms) = time_ms(|| {
            solve_joint_analytic(
                &b,
                st.batch_per_pipeline(),
                st.model.seq_len,
                st.parallel.pipeline_stages,
                &opts,
            )
        });
        println!(
            "| ({id}) | {} | 16 | {ms:.0} | -> {}",
            st.batch_per_pipeline(),
            &j.notation()[..j.notation().len().min(60)]
        );
        assert!(ms < 60_000.0, "paper budget exceeded");
        joint_rows.push(Json::obj(vec![
            ("setting", Json::Num(id as f64)),
            ("batch_per_pipeline", Json::Num(st.batch_per_pipeline() as f64)),
            ("granularity", Json::Num(16.0)),
            ("wall_ms", Json::Num(ms)),
        ]));
    }

    // ---- joint solver: engine (parallel) vs sequential oracle ----
    // Setting (8), the deep-pipeline joint regime. Plans are bit-identical
    // (enforced by tests/solver_joint_equivalence.rs; spot re-checked
    // here); only the wall clock may differ.
    println!("\n## joint solver: parallel engine vs sequential oracle (setting (8))");
    let st8 = presets::setting(8);
    let base8 = AnalyticModel::from_setting(&st8, 1);
    let jopts = JointOpts {
        granularity: 32,
        eps_ms: 0.1,
        max_microbatch: Some(4),
    };
    let (jb, jl, jk) = (
        st8.batch_per_pipeline().min(8),
        st8.model.seq_len,
        st8.parallel.pipeline_stages,
    );
    let mut jpar_wall = Vec::with_capacity(REPS);
    let mut jseq_wall = Vec::with_capacity(REPS);
    let mut jpar = None;
    let mut jseq = None;
    for _ in 0..REPS {
        let (r, ms) = time_ms(|| solve_joint_analytic(&base8, jb, jl, jk, &jopts));
        jpar_wall.push(ms);
        jpar = Some(r);
        let (r, ms) = time_ms(|| solve_joint_seq(|b| base8.with_microbatch(b), jb, jl, jk, &jopts));
        jseq_wall.push(ms);
        jseq = Some(r);
    }
    let (jpar, jseq) = (jpar.unwrap(), jseq.unwrap());
    assert_eq!(
        jpar.notation(),
        jseq.notation(),
        "joint parallel and sequential plans must be bit-identical"
    );
    assert!(jpar.latency_ms == jseq.latency_ms);
    let jps = Stats::from_samples(&jpar_wall);
    let jss = Stats::from_samples(&jseq_wall);
    let joint_speedup = jss.min / jps.min.max(1e-9);
    println!("sequential oracle: {} ms (min {:.2})", jss.pm(), jss.min);
    println!("parallel engine:   {} ms (min {:.2})", jps.pm(), jps.min);
    println!("speedup: {joint_speedup:.2}x on {threads} threads");

    // ---- machine-readable report (workspace root) ----
    let report = Json::obj(vec![
        ("bench", Json::Str("dp_solver".into())),
        ("setting", Json::Num(9.0)),
        ("stages", Json::Num(k as f64)),
        ("seq_len", Json::Num(l as f64)),
        ("threads", Json::Num(threads as f64)),
        ("reps", Json::Num(REPS as f64)),
        ("token_dp", Json::arr(rows)),
        (
            "seq_vs_par",
            Json::obj(vec![
                ("granularity", Json::Num(8.0)),
                ("eps_ms", Json::Num(0.1)),
                ("seq_wall_ms_min", Json::Num(ss.min)),
                ("seq_wall_ms_mean", Json::Num(ss.mean)),
                ("par_wall_ms_min", Json::Num(ps.min)),
                ("par_wall_ms_mean", Json::Num(ps.mean)),
                ("speedup_min_over_min", Json::Num(speedup)),
            ]),
        ),
        ("densify", Json::arr(densify_rows)),
        ("joint", Json::arr(joint_rows)),
        (
            "joint_seq_vs_par",
            Json::obj(vec![
                ("setting", Json::Num(8.0)),
                ("batch", Json::Num(jb as f64)),
                ("granularity", Json::Num(jopts.granularity as f64)),
                ("eps_ms", Json::Num(jopts.eps_ms)),
                ("seq_wall_ms_min", Json::Num(jss.min)),
                ("seq_wall_ms_mean", Json::Num(jss.mean)),
                ("par_wall_ms_min", Json::Num(jps.min)),
                ("par_wall_ms_mean", Json::Num(jps.mean)),
                ("speedup_min_over_min", Json::Num(joint_speedup)),
            ]),
        ),
    ]);
    // resolve at runtime: the binary may run on a different machine /
    // checkout than it was built on (cargo sets the var for bench runs;
    // fall back to the current directory elsewhere)
    let path = std::env::var("CARGO_MANIFEST_DIR")
        .map(|d| format!("{d}/../BENCH_dp_solver.json"))
        .unwrap_or_else(|_| "BENCH_dp_solver.json".into());
    std::fs::write(&path, report.to_string() + "\n").expect("write BENCH_dp_solver.json");
    println!("\nwrote {path}");

    // Acceptance gate (ISSUE 1): ≥4x over the sequential reference on a
    // multi-core host. Checked last so the JSON above records the run
    // even when the gate fails.
    if threads >= 8 {
        assert!(
            speedup >= 4.0,
            "acceptance: expected ≥4x on a multi-core host, got {speedup:.2}x on {threads} threads"
        );
    } else if speedup < 4.0 {
        println!("(note: <8 threads available; the ≥4x acceptance bound is not enforced here)");
    }
}
