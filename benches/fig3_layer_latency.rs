//! Bench: Fig. 3 — single-layer forward latency & throughput vs token
//! count (the saturation knee that motivates coarse-enough slices).
//!
//! Prints the analytic V100 curve for GPT3-1B (the paper's measurement)
//! plus, when `artifacts/` is built, the *measured* curve of the real
//! stage_fwd executable on this machine's CPU PJRT — same shape, different
//! hardware.

use terapipe::config::presets;
use terapipe::experiments::fig3_curve;
use terapipe::runtime::tensor::HostTensor;
use terapipe::runtime::{stage_exe_names, StageRuntime};
use terapipe::util::Stats;

fn main() {
    println!("# Fig. 3 — per-layer forward time / throughput vs #tokens");
    println!("\n## analytic V100, GPT3-1B layer (paper's setting)");
    println!("| tokens | fwd ms | tokens/ms |");
    for (t, ms, tp) in fig3_curve(&presets::gpt3_1b(), 2048) {
        println!("| {t} | {ms:.3} | {tp:.1} |");
    }

    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("\n(measured curve skipped: run `make artifacts` first)");
        return;
    }
    println!("\n## measured on this machine (CPU PJRT, real stage_fwd executable)");
    let manifest = terapipe::runtime::manifest::Manifest::load(&dir).unwrap();
    let m = manifest.model.clone();
    let rt = StageRuntime::load(
        &dir,
        &stage_exe_names(1 % m.num_stages, m.num_stages, &manifest.buckets),
    )
    .unwrap();
    let params = rt.manifest.load_init(&rt.manifest.init_stages[0]).unwrap();
    println!("| tokens | fwd ms (mean ± std of 10) | tokens/ms |");
    for &len in &manifest.buckets {
        let mut samples = Vec::new();
        for _ in 0..10 {
            let kv = HostTensor::zeros_f32(&m.kv_shape());
            let h = HostTensor::zeros_f32(&[m.batch, len, m.hidden]);
            let mut inputs: Vec<HostTensor> = params.clone();
            inputs.push(h);
            inputs.push(kv.clone());
            inputs.push(kv);
            inputs.push(HostTensor::scalar_i32(0));
            let (_, ms) =
                terapipe::util::time_ms(|| rt.run(&format!("stage_fwd_s{len}"), &inputs).unwrap());
            samples.push(ms);
        }
        let s = Stats::from_samples(&samples);
        println!("| {len} | {} | {:.1} |", s.pm(), len as f64 / s.mean);
    }
}
