//! Bench: Fig. 7 / Table 4 — GPT3-13B setting (5) with sequence length
//! 2048 → 8192 (batch shrinking 32 → 2 to fit memory, per the paper).
//! The reproduced claim: the TeraPipe speedup *grows* with sequence
//! length (paper: 1.40x → 2.76x → 4.97x → 7.83x).

use std::time::Instant;

use terapipe::experiments::fig7_rows;
use terapipe::solver::joint::JointOpts;

fn main() {
    let t0 = Instant::now();
    let opts = JointOpts {
        granularity: 16,
        eps_ms: 0.1,
        max_microbatch: Some(4),
    };
    println!("# Fig. 7 / Table 4 — sequence-length sweep, GPT3-13B setting (5)");
    println!("| L | B | w/o TeraPipe (s) | w/ TeraPipe (s) | speedup | paper speedup | w/ scheme |");
    let paper = [1.40, 2.76, 4.97, 7.83];
    let batches = [32, 8, 4, 2];
    for (((l, g, t, sp, scheme), p), b) in fig7_rows(&opts).into_iter().zip(paper).zip(batches) {
        let short = if scheme.len() > 40 {
            format!("{}…", &scheme[..39])
        } else {
            scheme
        };
        println!("| {l} | {b} | {g:.3} | {t:.3} | {sp:.2}x | {p:.2}x | {short} |");
    }
    println!(
        "\nsolved + simulated the sweep in {:.1}s ({} threads)",
        t0.elapsed().as_secs_f64(),
        rayon::current_num_threads()
    );
}
