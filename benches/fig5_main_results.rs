//! Bench: Fig. 5 / Table 2 — iteration latency for all ten Table 1
//! settings, w/o TeraPipe (GPipe microbatch baseline) vs w/ TeraPipe
//! (exact joint batch+token DP), executed on the calibrated simulator.
//!
//! The paper's measured latencies are printed alongside; the claim being
//! reproduced is the *shape* — who wins, by what factor, and that settings
//! (2)/(3) see no win while (9)/(10) see the largest.

use std::time::Instant;

use terapipe::experiments::{fig5_all, render_fig5};
use terapipe::solver::joint::JointOpts;

fn main() {
    let t0 = Instant::now();
    println!(
        "(joint solver: parallel anti-diagonal engine, {} threads)",
        rayon::current_num_threads()
    );
    let opts = JointOpts {
        granularity: 16,
        eps_ms: 0.1,
        max_microbatch: Some(8),
    };
    let rows = fig5_all(&opts);
    println!("# Fig. 5 / Table 2 — all Table 1 settings (simulated testbed)");
    print!("{}", render_fig5(&rows));
    println!("\nsummary:");
    for r in &rows {
        println!(
            "  setting ({:>2}) {:<10} speedup {:.2}x (paper {:.2}x)",
            r.setting,
            r.model_name,
            r.speedup,
            r.paper_gpipe_s / r.paper_terapipe_s
        );
    }
    let by_model_max = rows.iter().map(|r| r.speedup).fold(0.0f64, f64::max);
    println!(
        "\nmax speedup {:.2}x; solved+simulated all 10 settings in {:.1}s",
        by_model_max,
        t0.elapsed().as_secs_f64()
    );
}
