//! Bench: end-to-end real-execution pipeline — per-step wall time of the
//! threaded PJRT coordinator under different slicings, on this machine.
//!
//! This is the real-hardware analogue of Fig. 5/6: the same trade-off
//! (too few slices → bubbles; too many → per-slice overhead) measured on
//! the actual three-layer stack instead of the simulator. Single-core CPU
//! numbers — the *ordering*, not the magnitudes, is the signal.

use std::path::PathBuf;

use terapipe::coordinator::{Trainer, TrainConfig};
use terapipe::data::{synthetic_corpus, Batcher};
use terapipe::runtime::tensor::HostTensor;
use terapipe::runtime::{stage_exe_names, StageRuntime};
use terapipe::util::Stats;

/// §Perf L3 microbench: one stage_fwd call via (a) the naive path that
/// deep-clones the parameter tensors into the input vec per call (the
/// pre-optimization coordinator), vs (b) borrowed host tensors, vs
/// (c) cached parameter literals (current hot path). Isolates the two
/// optimization iterations recorded in EXPERIMENTS.md §Perf.
fn hot_path_microbench(dir: &PathBuf) {
    let manifest = terapipe::runtime::manifest::Manifest::load(dir).unwrap();
    let m = manifest.model.clone();
    let exe_names = stage_exe_names(1 % m.num_stages, m.num_stages, &manifest.buckets);
    let rt = StageRuntime::load(dir, &exe_names).unwrap();
    let params = rt.manifest.load_init(&rt.manifest.init_stages[0]).unwrap();
    let len = *manifest.buckets.iter().max().unwrap();
    let exe = format!("stage_fwd_s{len}");
    let h = HostTensor::zeros_f32(&[m.batch, len, m.hidden]);
    let kv = HostTensor::zeros_f32(&m.kv_shape());
    let off = HostTensor::scalar_i32(0);
    let param_lits: Vec<xla::Literal> = params.iter().map(|p| p.to_literal().unwrap()).collect();
    let reps = 10;

    let time = |f: &mut dyn FnMut()| -> Stats {
        f(); // warm-up
        let samples: Vec<f64> = (0..reps)
            .map(|_| terapipe::util::time_ms(|| f()).1)
            .collect();
        Stats::from_samples(&samples)
    };

    let mut naive = || {
        let mut inputs: Vec<HostTensor> = params.clone();
        inputs.push(h.clone());
        inputs.push(kv.clone());
        inputs.push(kv.clone());
        inputs.push(off.clone());
        rt.run(&exe, &inputs).unwrap();
    };
    let mut borrowed = || {
        let mut inputs: Vec<&HostTensor> = params.iter().collect();
        inputs.extend([&h, &kv, &kv, &off]);
        rt.run_refs(&exe, &inputs).unwrap();
    };
    let mut cached = || {
        let h_l = h.to_literal().unwrap();
        let k_l = kv.to_literal().unwrap();
        let v_l = kv.to_literal().unwrap();
        let o_l = off.to_literal().unwrap();
        let mut args: Vec<&xla::Literal> = param_lits.iter().collect();
        args.extend([&h_l, &k_l, &v_l, &o_l]);
        rt.run_literal_refs(&exe, &args).unwrap();
    };

    println!("\n## hot-path microbench ({exe}, mean ± std of {reps})");
    println!("| variant | ms |");
    println!("| clone params per call (before) | {} |", time(&mut naive).pm());
    println!("| borrowed host tensors (iter 1) | {} |", time(&mut borrowed).pm());
    println!("| cached param literals (iter 2) | {} |", time(&mut cached).pm());
}

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    hot_path_microbench(&dir);
    let steps = 6usize; // first step is warm-up, stats over the rest

    println!("# e2e pipelined training step time vs slicing (real PJRT stack)");
    println!("| slicing | slices | step ms (mean ± std of {}) | tok/s |", steps - 1);
    for slicing in [
        vec![128usize],
        vec![64, 64],
        vec![64, 32, 32],
        vec![64, 32, 16, 16],
        vec![32, 32, 32, 32],
        vec![16; 8],
    ] {
        let cfg = TrainConfig {
            slicing: slicing.clone(),
            steps,
            ..Default::default()
        };
        let mut t = match Trainer::new(&dir, cfg) {
            Ok(t) => t,
            Err(e) => {
                println!("| {slicing:?} | - | unavailable: {e} | - |");
                continue;
            }
        };
        let m = t.model.clone();
        let corpus = synthetic_corpus(1 << 15, 3);
        let mut batcher = Batcher::new(&corpus, m.batch, m.seq_len, 1);
        let reports = t.train(|| batcher.next_batch(), |_| {}).unwrap();
        let times: Vec<f64> = reports[1..].iter().map(|r| r.wall_ms).collect();
        let s = Stats::from_samples(&times);
        let toks = m.batch * m.seq_len;
        println!(
            "| {:?} | {} | {} | {:.0} |",
            slicing,
            slicing.len(),
            s.pm(),
            toks as f64 / (s.mean / 1e3)
        );
    }
}
