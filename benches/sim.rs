//! Bench: the simulator fast path. Plan validation moved onto the hot
//! path once the solvers went interactive (PR 1–3): `planner::validate`,
//! `terapipe autotune`, and the 220-case differential suite all replay
//! plans through the simulator. This bench times the three engines plus
//! the batched fan-out and emits a machine-readable `BENCH_sim.json` at
//! the workspace root (same protocol as `BENCH_dp_solver.json` /
//! `BENCH_planner.json`).
//!
//! Measured per setting (paper Table 1 scales (5)/(8)/(9)):
//!
//! * full fwd+bwd schedules (irregular — the backward chains run in
//!   reverse id order): `simulate_ref` (retained oracle) vs the arena DES
//!   core, trace on and trace off;
//! * regular replay streams (the validation workload): oracle vs the
//!   auto-selected closed-form wavefront path;
//! * a batch of independent replays: sequential single-arena loop vs
//!   `simulate_many` across rayon.
//!
//! `--quick` runs a reduced matrix with few reps and no acceptance
//! gates — the CI bench-smoke job uses it to catch hot-path regressions
//! (compile errors, asserts, order-of-magnitude blowups) without full
//! bench runtimes.

use terapipe::config::presets;
use terapipe::perfmodel::analytic::{AnalyticModel, AnalyticPhase};
use terapipe::perfmodel::CostModel;
use terapipe::sim::engine::{simulate_many, simulate_opts, simulate_ref, SimArena};
use terapipe::sim::schedule::{build_plan, stream_plan};
use terapipe::sim::wavefront;
use terapipe::sim::Plan;
use terapipe::solver::uniform::uniform_scheme;
use terapipe::solver::JointScheme;
use terapipe::util::json::Json;
use terapipe::util::{time_ms, Stats};

/// Full fwd+bwd schedule of one Table 1 setting under the analytic
/// model: `parts` microbatches, each sliced uniformly at `gran` tokens.
fn fwd_bwd_plan(setting_id: u32, gran: u32) -> (Plan, u32, u32, u32) {
    let st = presets::setting(setting_id);
    let base = AnalyticModel::from_setting(&st, 1);
    let cost = AnalyticPhase { base: &base };
    let k = st.parallel.pipeline_stages;
    let parts = st.batch_per_pipeline();
    let slices = st.model.seq_len / gran;
    let scheme = uniform_scheme(&base, st.model.seq_len, k, slices, gran);
    let joint = JointScheme {
        parts: (0..parts).map(|_| (1u32, scheme.clone())).collect(),
        latency_ms: 0.0,
    };
    let plan = build_plan(&cost, &joint, k as usize, None, false);
    (plan, k, parts, slices)
}

/// Regular validation replay at the same scale: the K-stage replay
/// stream over the concatenated slice durations (`parts × slices` items
/// per stage) from the analytic model — exactly the plan shape
/// `planner::validate::replay_plan` builds (shared builder:
/// `sim::schedule::stream_plan`).
fn replay_stream_plan(setting_id: u32, gran: u32, jitter: f64) -> Plan {
    let st = presets::setting(setting_id);
    let base = AnalyticModel::from_setting(&st, 1);
    let parts = st.batch_per_pipeline();
    let mut durs = Vec::new();
    for _ in 0..parts {
        let mut ctx = 0u32;
        for _ in 0..st.model.seq_len / gran {
            durs.push((base.t(gran, ctx) + base.t_comm(gran)) * jitter);
            ctx += gran;
        }
    }
    stream_plan(&durs, st.parallel.pipeline_stages as usize)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let reps = if quick { 2 } else { 7 };
    let threads = rayon::current_num_threads();
    println!("# simulator fast path (reps={reps}, threads={threads}{})", if quick { ", --quick" } else { "" });

    // (setting, granularity) chosen so each full plan lands ~10–50k items
    // (hundreds of thousands of heap events in the reference engine)
    let matrix: &[(u32, u32)] = if quick { &[(5, 512)] } else { &[(5, 128), (8, 32), (9, 16)] };

    let mut des_rows: Vec<Json> = Vec::new();
    let mut wf_rows: Vec<Json> = Vec::new();
    let mut arena = SimArena::new();
    let mut setting9_arena_speedup = f64::NAN;
    let mut setting9_wavefront_speedup = f64::NAN;

    println!("\n## discrete-event core: reference vs arena (full fwd+bwd schedules)");
    println!("| setting | K | parts | slices | items | ref (ms) | arena+trace (ms) | arena no-trace (ms) | speedup |");
    for &(id, gran) in matrix {
        let (plan, k, parts, slices) = fwd_bwd_plan(id, gran);
        assert!(!wavefront::is_regular(&plan), "fwd+bwd plan must exercise the DES");
        let n = plan.items.len();
        let mut ref_wall = Vec::with_capacity(reps);
        let mut tr_wall = Vec::with_capacity(reps);
        let mut nt_wall = Vec::with_capacity(reps);
        let mut ref_mk = 0.0f64;
        let mut arena_mk = 0.0f64;
        for _ in 0..reps {
            let (r, ms) = time_ms(|| simulate_ref(&plan).unwrap());
            ref_wall.push(ms);
            ref_mk = r.makespan_ms;
            let (a, ms) = time_ms(|| arena.simulate_des(&plan, true).unwrap());
            tr_wall.push(ms);
            arena_mk = a.makespan_ms;
            let (a, ms) = time_ms(|| arena.simulate_des(&plan, false).unwrap());
            nt_wall.push(ms);
            assert_eq!(a.makespan_ms.to_bits(), arena_mk.to_bits());
        }
        // tolerance, not bit-equality: these plans have coincident finish
        // instants (identical parts), where the engines may legally
        // resolve ties differently (PERF.md §7) — in practice they have
        // agreed exactly on every tested shape, but CI should not bet on
        // unguaranteed tie behavior
        assert!(
            (ref_mk - arena_mk).abs() < 1e-9,
            "setting ({id}): arena {arena_mk} diverged from the reference {ref_mk}"
        );
        let rs = Stats::from_samples(&ref_wall);
        let ts = Stats::from_samples(&tr_wall);
        let ns = Stats::from_samples(&nt_wall);
        // min-over-reps is the steadiest estimator on a shared box
        let speedup = rs.min / ns.min.max(1e-9);
        if id == 9 {
            setting9_arena_speedup = speedup;
        }
        println!(
            "| ({id}) | {k} | {parts} | {slices} | {n} | {} | {} | {} | {speedup:.1}x |",
            rs.pm(),
            ts.pm(),
            ns.pm()
        );
        des_rows.push(Json::obj(vec![
            ("setting", Json::Num(id as f64)),
            ("granularity", Json::Num(gran as f64)),
            ("stages", Json::Num(k as f64)),
            ("parts", Json::Num(parts as f64)),
            ("slices_per_part", Json::Num(slices as f64)),
            ("items", Json::Num(n as f64)),
            ("ref_ms_min", Json::Num(rs.min)),
            ("ref_ms_mean", Json::Num(rs.mean)),
            ("arena_trace_ms_min", Json::Num(ts.min)),
            ("arena_trace_ms_mean", Json::Num(ts.mean)),
            ("arena_notrace_ms_min", Json::Num(ns.min)),
            ("arena_notrace_ms_mean", Json::Num(ns.mean)),
            ("speedup_min_over_min", Json::Num(speedup)),
        ]));
    }

    println!("\n## wavefront closed form vs reference (regular validation replays)");
    println!("| setting | K | stream | items | ref (ms) | wavefront (ms) | speedup |");
    for &(id, gran) in matrix {
        let plan = replay_stream_plan(id, gran, 1.0);
        assert!(wavefront::is_regular(&plan), "replay stream must probe regular");
        let n = plan.items.len();
        let stages = plan.stages;
        let stream = n / stages;
        let mut ref_wall = Vec::with_capacity(reps);
        let mut wf_wall = Vec::with_capacity(reps);
        let mut ref_mk = 0.0f64;
        let mut wf_mk = 0.0f64;
        for _ in 0..reps {
            let (r, ms) = time_ms(|| simulate_ref(&plan).unwrap());
            ref_wall.push(ms);
            ref_mk = r.makespan_ms;
            // the production path: probe + closed form, trace off
            let (w, ms) = time_ms(|| simulate_opts(&plan, false).unwrap());
            wf_wall.push(ms);
            wf_mk = w.makespan_ms;
        }
        assert!(
            (ref_mk - wf_mk).abs() < 1e-9,
            "setting ({id}): wavefront {wf_mk} diverged from reference {ref_mk}"
        );
        let rs = Stats::from_samples(&ref_wall);
        let ws = Stats::from_samples(&wf_wall);
        let speedup = rs.min / ws.min.max(1e-9);
        if id == 9 {
            setting9_wavefront_speedup = speedup;
        }
        println!("| ({id}) | {stages} | {stream} | {n} | {} | {} | {speedup:.0}x |", rs.pm(), ws.pm());
        wf_rows.push(Json::obj(vec![
            ("setting", Json::Num(id as f64)),
            ("granularity", Json::Num(gran as f64)),
            ("stages", Json::Num(stages as f64)),
            ("stream_len", Json::Num(stream as f64)),
            ("items", Json::Num(n as f64)),
            ("ref_ms_min", Json::Num(rs.min)),
            ("ref_ms_mean", Json::Num(rs.mean)),
            ("wavefront_ms_min", Json::Num(ws.min)),
            ("wavefront_ms_mean", Json::Num(ws.mean)),
            ("speedup_min_over_min", Json::Num(speedup)),
        ]));
    }

    // ---- batched replay: sequential single-arena loop vs simulate_many ----
    let batch_setting = if quick { 5 } else { 9 };
    let batch_gran = if quick { 512 } else { 16 };
    let nplans = if quick { 8 } else { 32 };
    println!("\n## batched replay: {nplans} validation plans, sequential vs simulate_many");
    let plans: Vec<Plan> = (0..nplans)
        .map(|i| replay_stream_plan(batch_setting, batch_gran, 1.0 + 0.002 * i as f64))
        .collect();
    let mut seq_wall = Vec::with_capacity(reps);
    let mut par_wall = Vec::with_capacity(reps);
    for _ in 0..reps {
        let (seq_mks, ms) = time_ms(|| {
            plans
                .iter()
                .map(|p| arena.simulate(p, false).unwrap().makespan_ms)
                .collect::<Vec<f64>>()
        });
        seq_wall.push(ms);
        let (par_mks, ms) = time_ms(|| simulate_many(&plans, false));
        par_wall.push(ms);
        for (s, p) in seq_mks.iter().zip(&par_mks) {
            assert_eq!(s.to_bits(), p.as_ref().unwrap().makespan_ms.to_bits());
        }
    }
    let ss = Stats::from_samples(&seq_wall);
    let ps = Stats::from_samples(&par_wall);
    let batch_speedup = ss.min / ps.min.max(1e-9);
    println!("sequential: {} ms (min {:.2})", ss.pm(), ss.min);
    println!("batched:    {} ms (min {:.2})", ps.pm(), ps.min);
    println!("speedup: {batch_speedup:.2}x on {threads} threads");

    // ---- machine-readable report (workspace root) ----
    let report = Json::obj(vec![
        ("bench", Json::Str("sim".into())),
        ("quick", Json::Num(if quick { 1.0 } else { 0.0 })),
        ("reps", Json::Num(reps as f64)),
        ("threads", Json::Num(threads as f64)),
        ("des", Json::arr(des_rows)),
        ("wavefront", Json::arr(wf_rows)),
        (
            "batched",
            Json::obj(vec![
                ("setting", Json::Num(batch_setting as f64)),
                ("plans", Json::Num(nplans as f64)),
                ("seq_wall_ms_min", Json::Num(ss.min)),
                ("seq_wall_ms_mean", Json::Num(ss.mean)),
                ("par_wall_ms_min", Json::Num(ps.min)),
                ("par_wall_ms_mean", Json::Num(ps.mean)),
                ("speedup_min_over_min", Json::Num(batch_speedup)),
            ]),
        ),
    ]);
    // resolve at runtime: the binary may run on a different machine /
    // checkout than it was built on (cargo sets the var for bench runs;
    // fall back to the current directory elsewhere)
    let path = std::env::var("CARGO_MANIFEST_DIR")
        .map(|d| format!("{d}/../BENCH_sim.json"))
        .unwrap_or_else(|_| "BENCH_sim.json".into());
    std::fs::write(&path, report.to_string() + "\n").expect("write BENCH_sim.json");
    println!("\nwrote {path}");

    // Acceptance gates (ISSUE 4), checked last so the JSON above records
    // the run even when a gate fails. Both speedups are algorithmic
    // (single replay, one thread), so no thread-count guard applies.
    if !quick {
        assert!(
            setting9_arena_speedup >= 5.0,
            "acceptance: arena DES must be ≥5x the reference on setting (9) replay, got {setting9_arena_speedup:.2}x"
        );
        assert!(
            setting9_wavefront_speedup >= 20.0,
            "acceptance: wavefront must be ≥20x the reference on regular plans, got {setting9_wavefront_speedup:.2}x"
        );
    }
}
