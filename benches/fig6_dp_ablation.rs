//! Bench: Fig. 6 / Table 3 — the DP ablation: uniform #slices sweep vs
//! the DP scheme on GPT3-44B setting (8) (1..16 slices) and GPT3-175B
//! setting (9) (1..128 slices), as in the paper.

use std::time::Instant;

use terapipe::experiments::fig6_rows;
use terapipe::solver::joint::JointOpts;

fn main() {
    let t0 = Instant::now();
    let opts = JointOpts {
        granularity: 16,
        eps_ms: 0.1,
        max_microbatch: Some(4),
    };
    for (setting, max_slices, paper_gain) in [(8u32, 16u32, 1.12), (9, 128, 1.04)] {
        println!("\n# Fig. 6({}) — setting ({setting})", if setting == 8 { 'a' } else { 'b' });
        println!("| algorithm | scheme | latency (s) | TFLOPs/GPU |");
        let rows = fig6_rows(setting, max_slices, &opts);
        for (label, scheme, lat, tf) in &rows {
            let short = if scheme.len() > 44 {
                format!("{}…", &scheme[..43])
            } else {
                scheme.clone()
            };
            println!("| {label} | {short} | {lat:.3} | {tf:.4} |");
        }
        let dp = rows.last().unwrap().2;
        let best_uniform = rows[..rows.len() - 1]
            .iter()
            .map(|r| r.2)
            .fold(f64::INFINITY, f64::min);
        println!(
            "DP vs best uniform: {:.3}x faster (paper: {:.2}x)",
            best_uniform / dp,
            paper_gain
        );
    }
    println!(
        "\nsolved + simulated both ablations in {:.1}s ({} threads)",
        t0.elapsed().as_secs_f64(),
        rayon::current_num_threads()
    );
}
