//! Bench: the online planner service — cold vs warm re-solve, cost-table
//! cache hit/miss paths, and the 4-lane unrolled Alg-1 inner loop vs the
//! scalar reference. Emits `BENCH_planner.json` at the workspace root in
//! the PR 1 JSON protocol (PERF.md) so the planner trajectory is tracked
//! across PRs.
//!
//! Shapes to expect: the warm re-solve beats cold-with-densify by roughly
//! the densification cost (the cache rescale is one multiply pass) plus a
//! handful of feasibility probes (gallop vs full binary search); the
//! scaled-table cache hit path costs nothing but the solve itself; the
//! lanes inner loop buys a constant factor per DP. Outputs are
//! bit-identical across all pairs (asserted here; pinned by the property
//! suites).

use terapipe::config::presets;
use terapipe::perfmodel::analytic::AnalyticModel;
use terapipe::perfmodel::{ScaledModel, TableCostModel};
use terapipe::planner::drift::LatencySample;
use terapipe::planner::{warm, Planner, PlannerConfig};
use terapipe::solver::dp::{solve_fixed_tmax, solve_fixed_tmax_ref, solve_tokens_table};
use terapipe::util::json::Json;
use terapipe::util::{time_ms, Stats};

const REPS: usize = 5;

fn main() {
    println!("# Online planner: cold vs warm re-solve, cache paths, lanes inner loop");
    let setting = presets::setting(9); // K=96, L=2048 — the paper-scale instance
    let base = AnalyticModel::from_setting(&setting, 1);
    let l = setting.model.seq_len;
    let k = setting.parallel.pipeline_stages;
    let gran = 16u32;
    let eps = 0.1;
    let threads = rayon::current_num_threads();
    println!("setting (9): K={k}, L={l}, g={gran}, eps={eps}, threads {threads}");

    // ---- cold re-solve (the pre-planner baseline: densify + solve) vs
    //      warm re-solve (cache rescale + gallop-seeded enumeration)
    //      across a slowdown delta ----
    println!("\n## re-solve after a 1.2x slowdown: cold (densify + solve) vs warm (rescale + seeded)");
    let factor = 1.2f64;
    let mut cold_wall = Vec::with_capacity(REPS);
    let mut warm_wall = Vec::with_capacity(REPS);
    let mut cold_scheme = None;
    let mut warm_scheme = None;
    let (base_table, densify_ms) = time_ms(|| TableCostModel::build(&base, l, gran));
    // the warm seed a live planner would carry: the pre-delta boundary
    let (pre, _) = solve_tokens_table(&base_table, k, eps);
    for _ in 0..REPS {
        let (r, ms) = time_ms(|| {
            // cold: a from-scratch solver has to densify the drifted model
            let scaled = ScaledModel { inner: &base, compute: factor, comm: 1.0 };
            let table = TableCostModel::build(&scaled, l, gran);
            solve_tokens_table(&table, k, eps).0
        });
        cold_wall.push(ms);
        cold_scheme = Some(r);
        let (r, ms) = time_ms(|| {
            // warm: rescale the cached diagonals, seed from the scaled hint
            let table = base_table.rescaled(factor, 1.0);
            let hint = pre.t_max_ms * factor;
            warm::solve_tokens_table_warm(&table, k, eps, hint, warm::DEFAULT_WINDOW).0
        });
        warm_wall.push(ms);
        warm_scheme = Some(r);
    }
    let (cold_scheme, warm_scheme) = (cold_scheme.unwrap(), warm_scheme.unwrap());
    assert_eq!(cold_scheme.lens, warm_scheme.lens, "warm must be bit-identical");
    assert!(cold_scheme.latency_ms == warm_scheme.latency_ms);
    let cs = Stats::from_samples(&cold_wall);
    let ws = Stats::from_samples(&warm_wall);
    let resolve_speedup = cs.min / ws.min.max(1e-9);
    println!("densify-once cost (amortized away by the cache): {densify_ms:.2} ms");
    println!("cold re-solve: {} ms (min {:.2})", cs.pm(), cs.min);
    println!("warm re-solve: {} ms (min {:.2})", ws.pm(), ws.min);
    println!("speedup: {resolve_speedup:.2}x");

    // ---- cache hit/miss paths ----
    println!("\n## cost-table cache paths (build = miss, rescale = scaled miss, hit = Arc clone)");
    let mut build_t = Vec::with_capacity(REPS);
    let mut rescale_t = Vec::with_capacity(REPS);
    let mut hit_t = Vec::with_capacity(REPS);
    for rep in 0..REPS {
        let mut p = Planner::new(
            "bench",
            base.clone(),
            l,
            k,
            PlannerConfig { granularity: gran, eps_ms: eps, ..Default::default() },
        );
        let (_, ms) = time_ms(|| p.plan().num_slices()); // base miss: densify + cold solve
        build_t.push(ms);
        // scaled miss: rescale + warm solve
        let (_, ms) = time_ms(|| p.on_slowdown(1.0 + 0.1 * (rep + 1) as f64));
        rescale_t.push(ms);
        let (_, ms) = time_ms(|| p.replan_now()); // pure hit: cached table + warm solve
        hit_t.push(ms);
    }
    let bs = Stats::from_samples(&build_t);
    let rs = Stats::from_samples(&rescale_t);
    let hs = Stats::from_samples(&hit_t);
    println!("| path | wall ms (mean ± std of {REPS}) | min |");
    println!("| base miss (densify + cold solve) | {} | {:.2} |", bs.pm(), bs.min);
    println!("| scaled miss (rescale + warm solve) | {} | {:.2} |", rs.pm(), rs.min);
    println!("| hit (cached table + warm solve) | {} | {:.2} |", hs.pm(), hs.min);

    // ---- drift loop end-to-end ----
    println!("\n## drift-aware replan loop (detect from samples + warm re-solve)");
    let mut p = Planner::new(
        "bench-drift",
        base.clone(),
        l,
        k,
        PlannerConfig { granularity: gran, eps_ms: eps, ..Default::default() },
    );
    p.plan();
    let truth = ScaledModel { inner: base.clone(), compute: 1.3, comm: 1.0 };
    let max_units = l / gran;
    let (fed, drift_ms) = time_ms(|| {
        use terapipe::perfmodel::CostModel;
        let mut rng = terapipe::util::Rng::new(11);
        let mut fed = 0u32;
        loop {
            let iu = 1 + rng.below(max_units.min(8));
            let ju = rng.below(max_units - iu + 1);
            let (i, j) = (iu * gran, ju * gran);
            let ms = truth.t(i, j) + truth.t_comm(i);
            fed += 1;
            if p.on_sample(LatencySample { i, j, ms }).is_some() || fed > 512 {
                break;
            }
        }
        fed
    });
    println!("detected + replanned after {fed} samples in {drift_ms:.2} ms total");
    let cache = p.cache_stats();
    println!(
        "cache over the loop: {} densifications, {} rescales, {} hits",
        cache.base_misses,
        cache.rescales,
        cache.base_hits + cache.scaled_hits
    );

    // ---- lanes vs scalar inner loop (per-DP) ----
    println!("\n## Alg-1 inner loop: 4-lane unrolled vs scalar reference (g=8, budget sweep)");
    let fine = TableCostModel::build(&base, l, 8);
    let n = fine.units();
    let budgets: Vec<f64> = (1..=10)
        .map(|s| (fine.at(n, 0) + fine.comm_at(n)) * s as f64 / 10.0)
        .collect();
    let mut lanes_wall = Vec::with_capacity(REPS);
    let mut scalar_wall = Vec::with_capacity(REPS);
    for _ in 0..REPS {
        let (sols, ms) = time_ms(|| {
            budgets.iter().filter(|&&b| solve_fixed_tmax(&fine, b).is_some()).count()
        });
        lanes_wall.push(ms);
        let (ref_sols, ms) = time_ms(|| {
            budgets.iter().filter(|&&b| solve_fixed_tmax_ref(&fine, b).is_some()).count()
        });
        scalar_wall.push(ms);
        assert_eq!(sols, ref_sols);
    }
    let ls = Stats::from_samples(&lanes_wall);
    let ss = Stats::from_samples(&scalar_wall);
    let lanes_speedup = ss.min / ls.min.max(1e-9);
    println!("scalar reference: {} ms (min {:.2})", ss.pm(), ss.min);
    println!("4-lane unrolled:  {} ms (min {:.2})", ls.pm(), ls.min);
    println!("per-DP speedup: {lanes_speedup:.2}x");

    // ---- machine-readable report (workspace root, PR 1 protocol) ----
    let report = Json::obj(vec![
        ("bench", Json::Str("planner".into())),
        ("setting", Json::Num(9.0)),
        ("stages", Json::Num(k as f64)),
        ("seq_len", Json::Num(l as f64)),
        ("granularity", Json::Num(gran as f64)),
        ("eps_ms", Json::Num(eps)),
        ("threads", Json::Num(threads as f64)),
        ("reps", Json::Num(REPS as f64)),
        (
            "cold_vs_warm_resolve",
            Json::obj(vec![
                ("delta_compute_factor", Json::Num(factor)),
                ("densify_ms", Json::Num(densify_ms)),
                ("cold_wall_ms_min", Json::Num(cs.min)),
                ("cold_wall_ms_mean", Json::Num(cs.mean)),
                ("warm_wall_ms_min", Json::Num(ws.min)),
                ("warm_wall_ms_mean", Json::Num(ws.mean)),
                ("speedup_min_over_min", Json::Num(resolve_speedup)),
            ]),
        ),
        (
            "cache_paths",
            Json::obj(vec![
                ("base_miss_ms_min", Json::Num(bs.min)),
                ("scaled_miss_ms_min", Json::Num(rs.min)),
                ("hit_ms_min", Json::Num(hs.min)),
            ]),
        ),
        (
            "drift_loop",
            Json::obj(vec![
                ("samples_to_detect", Json::Num(fed as f64)),
                ("total_ms", Json::Num(drift_ms)),
            ]),
        ),
        (
            "lanes_inner_loop",
            Json::obj(vec![
                ("granularity", Json::Num(8.0)),
                ("budgets", Json::Num(budgets.len() as f64)),
                ("scalar_ms_min", Json::Num(ss.min)),
                ("lanes_ms_min", Json::Num(ls.min)),
                ("speedup_min_over_min", Json::Num(lanes_speedup)),
            ]),
        ),
    ]);
    let path = std::env::var("CARGO_MANIFEST_DIR")
        .map(|d| format!("{d}/../BENCH_planner.json"))
        .unwrap_or_else(|_| "BENCH_planner.json".into());
    std::fs::write(&path, report.to_string() + "\n").expect("write BENCH_planner.json");
    println!("\nwrote {path}");
}
