//! Bench: the native CPU stage backend — the execution engine behind
//! `terapipe train`/`measure` in the default build. Emits a
//! machine-readable `BENCH_exec.json` at the workspace root (same
//! protocol as `BENCH_sim.json` / `BENCH_dp_solver.json`).
//!
//! Measured:
//!
//! * kernel microbench: GFLOP/s of the blocked matmul — under both the
//!   scalar and (when active) the AVX2+FMA dispatch tiers — vs the
//!   naive reference loops on model-relevant shapes (gated in non-quick
//!   runs: blocked ≥1.5× ref, and simd ≥1.5× blocked on hosts where the
//!   simd tier is active, skipped with a printed notice otherwise);
//! * per-bucket cell latency: `stage_fwd` alone and `stage_fwd +
//!   stage_bwd` (the `CostModel` unit) at empty and near-full context —
//!   the real-execution analogue of Fig. 3's latency-vs-tokens curve;
//! * steady-state allocation count of the cell-level `_into` hot path
//!   (`stage_fwd_into` + `stage_bwd_into`), asserted **zero** once the
//!   per-thread scratch arena is warm — pinned with a counting global
//!   allocator, under the scalar tier *and* (when active) the simd
//!   tier;
//! * one full pipelined training step through the threaded coordinator
//!   vs *serial* execution of the same slices (the sum of every traced
//!   per-slice fwd/bwd time across all stages) — how much of the
//!   schedule's overlap survives on this machine — plus the step's
//!   allocation count as telemetry (the trait boundary allocates output
//!   tensors by design; only the cell hot path is required to be
//!   allocation-free);
//! * `obs` section (separate `BENCH_obs.json`): the same pipelined step
//!   traced vs untraced — the span recorder's wall overhead (gated ≤ 3%
//!   in non-quick runs) and its steady-state allocation delta (gated 0) —
//!   plus a flight-recorder leg: the traced step with per-step
//!   `FlightRecorder::record_step` into a small ring vs without, gating
//!   the recorder's wall overhead ≤ 1% and the ring's steady-state
//!   (slot-reuse) allocations at **zero** once every slot has been
//!   filled once.
//!
//! `--quick` runs a reduced model with few reps and no perf gate — the
//! CI bench-smoke job uses it to catch compile errors and
//! order-of-magnitude blowups without full bench runtimes. The zero-alloc
//! assertion runs in both modes.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use terapipe::backend::math::{matmul_into, matmul_ref};
use terapipe::backend::native::init_stage;
use terapipe::backend::simd::{active_tier, set_tier, Tier};
use terapipe::backend::{cell, BackendSpec, NativeSpec, StageBackend};
use terapipe::coordinator::{TrainConfig, Trainer};
use terapipe::data::{synthetic_corpus, Batcher};
use terapipe::obs::flight::{plan_fingerprint, FlightRecorder};
use terapipe::runtime::manifest::ModelDims;
use terapipe::runtime::tensor::HostTensor;
use terapipe::util::json::Json;
use terapipe::util::{time_ms, Stats};

/// Counting allocator: every alloc/realloc/alloc_zeroed bumps a global
/// counter, so a code region's heap traffic is observable as a delta.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, s: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, s)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn bench_spec(quick: bool) -> NativeSpec {
    let (hidden, heads, layers, stages, seq_len, batch, gran) = if quick {
        (32, 4, 1, 2, 64, 2, 16)
    } else {
        (128, 8, 2, 4, 256, 4, 32)
    };
    NativeSpec::new(
        ModelDims {
            vocab: 256,
            hidden,
            num_heads: heads,
            layers_per_stage: layers,
            num_stages: stages,
            seq_len,
            batch,
            block_ctx: gran,
            seed: 42,
        },
        gran,
    )
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let reps = if quick { 2 } else { 5 };
    let spec = bench_spec(quick);
    let m = spec.model();
    let buckets = spec.buckets();
    println!(
        "# native exec backend (H={}, NH={}, NL={}, K={}, L={}, B={}, reps={reps}{})",
        m.hidden,
        m.num_heads,
        m.layers_per_stage,
        m.num_stages,
        m.seq_len,
        m.batch,
        if quick { ", --quick" } else { "" }
    );

    // ---- kernel microbench: simd vs scalar-blocked vs naive ref ----
    // The "blocked" numbers pin the scalar dispatch tier so the simd
    // column is a tier-vs-tier comparison over identical outer blocking;
    // the detected tier is restored afterwards so the pipeline sections
    // below run what production runs.
    let detected = active_tier();
    let simd_on = detected == Tier::Avx2;
    if !simd_on {
        println!("note: AVX2+FMA tier off (unsupported host or TERAPIPE_NO_SIMD) — simd legs skipped");
    }
    let shapes: &[(usize, usize, usize)] = if quick {
        &[(64, 32, 128), (1, 64, 512)]
    } else {
        &[(256, 128, 512), (512, 256, 128), (128, 512, 256), (1, 256, 4096)]
    };
    let mut kernel_rows: Vec<Json> = Vec::new();
    let mut best_simd_speedup = 0.0f64;
    println!("\n## matmul GFLOP/s (simd vs blocked vs ref)");
    println!("| m | k | n | simd | blocked | ref | blocked/ref | simd/blocked |");
    for &(mm, kk, nn) in shapes {
        let a = vec![0.5f32; mm * kk];
        let b = vec![0.25f32; kk * nn];
        let mut out = vec![0f32; mm * nn];
        let flops = 2.0 * (mm * kk * nn) as f64;
        set_tier(Tier::Scalar);
        matmul_into(&a, &b, mm, kk, nn, &mut out); // warm pack buffers
        let blocked_ms = (0..reps.max(3))
            .map(|_| time_ms(|| matmul_into(&a, &b, mm, kk, nn, &mut out)).1)
            .fold(f64::INFINITY, f64::min);
        let simd_ms = if simd_on {
            set_tier(Tier::Avx2);
            matmul_into(&a, &b, mm, kk, nn, &mut out); // warm under the simd tier
            (0..reps.max(3))
                .map(|_| time_ms(|| matmul_into(&a, &b, mm, kk, nn, &mut out)).1)
                .fold(f64::INFINITY, f64::min)
        } else {
            f64::INFINITY
        };
        set_tier(detected);
        let ref_ms = (0..reps.max(3))
            .map(|_| time_ms(|| std::hint::black_box(matmul_ref(&a, &b, mm, kk, nn))).1)
            .fold(f64::INFINITY, f64::min);
        let gf_blocked = flops / (blocked_ms * 1e6);
        let gf_ref = flops / (ref_ms * 1e6);
        let speedup = ref_ms / blocked_ms.max(1e-9);
        let (simd_col, ratio_col) = if simd_on {
            let gf_simd = flops / (simd_ms * 1e6);
            let simd_speedup = blocked_ms / simd_ms.max(1e-9);
            best_simd_speedup = best_simd_speedup.max(simd_speedup);
            (format!("{gf_simd:.2}"), format!("{simd_speedup:.2}x"))
        } else {
            ("-".into(), "-".into())
        };
        println!(
            "| {mm} | {kk} | {nn} | {simd_col} | {gf_blocked:.2} | {gf_ref:.2} | {speedup:.2}x | {ratio_col} |"
        );
        let mut row = vec![
            ("m", Json::Num(mm as f64)),
            ("k", Json::Num(kk as f64)),
            ("n", Json::Num(nn as f64)),
            ("blocked_gflops", Json::Num(gf_blocked)),
            ("ref_gflops", Json::Num(gf_ref)),
            ("speedup", Json::Num(speedup)),
        ];
        if simd_on {
            row.push(("simd_gflops", Json::Num(flops / (simd_ms * 1e6))));
            row.push(("simd_speedup", Json::Num(blocked_ms / simd_ms.max(1e-9))));
        }
        kernel_rows.push(Json::obj(row));
        if !quick {
            assert!(
                speedup >= 1.5,
                "blocked matmul ({mm},{kk},{nn}) only {speedup:.2}x over ref (gate: 1.5x)"
            );
        }
    }
    if !quick {
        if simd_on {
            assert!(
                best_simd_speedup >= 1.5,
                "simd tier best speedup over scalar-blocked is {best_simd_speedup:.2}x (gate: 1.5x)"
            );
        } else {
            println!("simd ≥ 1.5x gate skipped: AVX2+FMA tier not active on this host");
        }
    }

    // ---- per-bucket cell latency (middle stage, like `measure`) ----
    let mut be = spec
        .build(1 % m.num_stages, m.num_stages, None)
        .expect("build bench backend");
    let kv = HostTensor::zeros_f32(&m.kv_shape());
    let mut bucket_rows: Vec<Json> = Vec::new();
    println!("\n## per-bucket stage latency (ms, mean ± std)");
    println!("| i (slice) | j (ctx) | fwd | fwd+bwd |");
    for &i in &buckets {
        // empty and near-full context — one point when they coincide (i = L)
        let both = [0usize, m.seq_len - i];
        let ctxs = if i == m.seq_len { &both[..1] } else { &both[..] };
        for &j in ctxs {
            let h = HostTensor::zeros_f32(&[m.batch, i, m.hidden]);
            let g_h = HostTensor::zeros_f32(&[m.batch, i, m.hidden]);
            let g_kv = HostTensor::zeros_f32(&m.kv_new_shape(i));
            let fwd: Vec<f64> = (0..reps)
                .map(|_| time_ms(|| be.stage_fwd(&h, &kv, &kv, j).unwrap()).1)
                .collect();
            let both: Vec<f64> = (0..reps)
                .map(|_| {
                    time_ms(|| {
                        be.stage_fwd(&h, &kv, &kv, j).unwrap();
                        be.stage_bwd(&h, &kv, &kv, j, &g_h, &g_kv, &g_kv).unwrap();
                    })
                    .1
                })
                .collect();
            let fs = Stats::from_samples(&fwd);
            let bs = Stats::from_samples(&both);
            println!("| {i} | {j} | {} | {} |", fs.pm(), bs.pm());
            bucket_rows.push(Json::obj(vec![
                ("i", Json::Num(i as f64)),
                ("j", Json::Num(j as f64)),
                ("fwd_ms_mean", Json::Num(fs.mean)),
                ("fwd_ms_min", Json::Num(fs.min)),
                ("fwd_bwd_ms_mean", Json::Num(bs.mean)),
                ("fwd_bwd_ms_min", Json::Num(bs.min)),
            ]));
        }
    }
    drop(be);

    // ---- allocation-free hot path: cell-level `_into` fwd+bwd ----
    // The trait boundary (StageBackend) allocates its output HostTensors
    // by design; the contract pinned here is that the *cell* hot path —
    // everything inside stage_fwd_into/stage_bwd_into — performs zero
    // heap allocations once the per-thread scratch arena is warm.
    // Measured once per available dispatch tier: the simd kernels must
    // preserve the contract, not just the scalar ones.
    let hot_path_allocs = || {
        let mut ps = init_stage(&m, 1 % m.num_stages);
        let s = buckets[0];
        let off = m.seq_len / 2;
        let per_act = m.batch * s * m.hidden;
        let per_ctx: usize = m.kv_shape().iter().product();
        let per_new: usize = m.kv_new_shape(s).iter().product();
        let h = vec![0.1f32; per_act];
        let k_ctx = vec![0.1f32; per_ctx];
        let v_ctx = vec![0.1f32; per_ctx];
        let g_h = vec![0.1f32; per_act];
        let g_know = vec![0.01f32; per_new];
        let g_vnow = vec![0.01f32; per_new];
        let mut h_out = vec![0f32; per_act];
        let mut k_new = vec![0f32; per_new];
        let mut v_new = vec![0f32; per_new];
        let mut g_h_in = vec![0f32; per_act];
        let mut g_kctx = vec![0f32; per_ctx];
        let mut g_vctx = vec![0f32; per_ctx];
        let mut iter = || {
            cell::stage_fwd_into(
                &m, s, off, &ps.params, &h, &k_ctx, &v_ctx, &mut h_out, &mut k_new, &mut v_new,
            );
            g_kctx.iter_mut().for_each(|x| *x = 0.0);
            g_vctx.iter_mut().for_each(|x| *x = 0.0);
            cell::stage_bwd_into(
                &m,
                s,
                off,
                &ps.params,
                &h,
                &k_ctx,
                &v_ctx,
                &g_h,
                &g_know,
                &g_vnow,
                &mut ps.grads,
                &mut g_h_in,
                &mut g_kctx,
                &mut g_vctx,
            );
        };
        for _ in 0..3 {
            iter(); // warm the scratch arena, cache pool, rayon pool
        }
        // min over a few iterations filters one-off lazy init elsewhere
        let mut deltas = Vec::new();
        for _ in 0..3 {
            let before = ALLOCS.load(Ordering::SeqCst);
            iter();
            deltas.push(ALLOCS.load(Ordering::SeqCst) - before);
        }
        (*deltas.iter().min().unwrap(), deltas)
    };
    set_tier(Tier::Scalar);
    let (steady_allocs, deltas) = hot_path_allocs();
    println!("\n## steady-state hot-path allocations (fwd+bwd, warm arena)");
    println!("scalar tier: allocations per iteration: {steady_allocs} (deltas {deltas:?})");
    assert_eq!(
        steady_allocs, 0,
        "warm cell hot path must be allocation-free, saw {deltas:?}"
    );
    let simd_steady_allocs = if simd_on {
        set_tier(Tier::Avx2);
        let (sa, sd) = hot_path_allocs();
        println!("simd tier:   allocations per iteration: {sa} (deltas {sd:?})");
        assert_eq!(
            sa, 0,
            "warm cell hot path must stay allocation-free under the simd tier, saw {sd:?}"
        );
        sa as f64
    } else {
        -1.0
    };
    set_tier(detected);

    // ---- pipelined step vs serial execution of the same slices ----
    let slice_len = spec.buckets()[0];
    let slicing = vec![slice_len; m.seq_len / slice_len];
    let steps = 1 + reps; // step 0 is warmup
    let cfg = TrainConfig {
        slicing: slicing.clone(),
        steps,
        trace: true,
        seed: 4,
        ..Default::default()
    };
    let mut t = Trainer::with_spec(spec.clone(), cfg).expect("trainer");
    let corpus = synthetic_corpus(1 << 14, 7);
    let mut batcher = Batcher::new(&corpus, m.batch, m.seq_len, 4);
    let mut pipelined = Vec::new();
    let mut serial = Vec::new();
    let mut step_allocs: u64 = u64::MAX;
    for step in 0..steps {
        let batches: Vec<_> = (0..1).map(|_| batcher.next_batch()).collect();
        let allocs_before = ALLOCS.load(Ordering::SeqCst);
        let (res, wall_ms) = time_ms(|| t.step(&batches));
        res.expect("bench step");
        if step == 0 {
            continue; // warmup: cold caches, lazy thread spin-up
        }
        step_allocs = step_allocs.min(ALLOCS.load(Ordering::SeqCst) - allocs_before);
        // serial baseline: the same slices' traced fwd+bwd times summed
        // across all stages — what a one-thread, no-overlap execution of
        // this step's compute would cost
        let busy: f64 = t.last_timings().iter().map(|s| s.ms).sum();
        serial.push(busy);
        pipelined.push(wall_ms);
    }
    let ss = Stats::from_samples(&serial);
    let ps = Stats::from_samples(&pipelined);
    let speedup = ss.min / ps.min.max(1e-9);
    println!("\n## pipelined step vs serial slice execution ({} stages × {} slices)", m.num_stages, slicing.len());
    println!("serial (Σ traced slice fwd+bwd): {} ms (min {:.2})", ss.pm(), ss.min);
    println!("pipelined step wall:             {} ms (min {:.2})", ps.pm(), ps.min);
    println!("overlap speedup: {speedup:.2}x on {} worker threads", m.num_stages);
    println!("allocations per pipelined step (min, telemetry): {step_allocs}");

    // ---- machine-readable report (workspace root) ----
    let report = Json::obj(vec![
        ("bench", Json::Str("exec".into())),
        ("quick", Json::Num(if quick { 1.0 } else { 0.0 })),
        ("reps", Json::Num(reps as f64)),
        ("simd_tier_active", Json::Num(if simd_on { 1.0 } else { 0.0 })),
        (
            "model",
            Json::obj(vec![
                ("hidden", Json::Num(m.hidden as f64)),
                ("heads", Json::Num(m.num_heads as f64)),
                ("layers_per_stage", Json::Num(m.layers_per_stage as f64)),
                ("stages", Json::Num(m.num_stages as f64)),
                ("seq_len", Json::Num(m.seq_len as f64)),
                ("batch", Json::Num(m.batch as f64)),
            ]),
        ),
        ("kernels", Json::arr(kernel_rows)),
        ("per_bucket", Json::arr(bucket_rows)),
        (
            "alloc",
            Json::obj(vec![
                ("hot_path_steady_allocs", Json::Num(steady_allocs as f64)),
                // -1 ⇒ simd tier not active on this host/run
                ("hot_path_steady_allocs_simd", Json::Num(simd_steady_allocs)),
                ("pipelined_step_allocs_min", Json::Num(step_allocs as f64)),
            ]),
        ),
        (
            "step",
            Json::obj(vec![
                ("slices", Json::Num(slicing.len() as f64)),
                ("serial_ms_min", Json::Num(ss.min)),
                ("serial_ms_mean", Json::Num(ss.mean)),
                ("pipelined_ms_min", Json::Num(ps.min)),
                ("pipelined_ms_mean", Json::Num(ps.mean)),
                ("overlap_speedup_min_over_min", Json::Num(speedup)),
            ]),
        ),
    ]);
    let path = std::env::var("CARGO_MANIFEST_DIR")
        .map(|d| format!("{d}/../BENCH_exec.json"))
        .unwrap_or_else(|_| "BENCH_exec.json".into());
    std::fs::write(&path, report.to_string() + "\n").expect("write BENCH_exec.json");
    println!("\nwrote {path}");

    // Sanity gate (skipped in --quick): overlapped execution must not be
    // pathologically slower than running the same slices serially. The
    // bound is loose — on few-core boxes the stage threads contend with
    // the kernels' own rayon parallelism — it exists to catch schedule
    // regressions (a serialized pipeline, a lost wakeup), not to promise
    // a speedup.
    if !quick {
        assert!(
            speedup > 0.5,
            "pipelined step is >2x slower than serial slice execution ({speedup:.2}x)"
        );
    }

    // ---- obs: recorder overhead on the pipelined step ----
    // Traced vs untraced execution of the same schedule (cfg.trace on in
    // both, so SliceTime collection is identical and the delta isolates
    // the span recorder). The recorder's contract is "a few ns per span,
    // zero steady-state allocations": the non-quick gates pin the wall
    // overhead ≤ 3% and the per-step allocation delta attributable to
    // the recorder at 0 (min over reps, so one-off per-thread slot
    // claims on first use don't count).
    let obs_steps = 1 + reps;
    let obs_run = |traced: bool| -> (f64, u64, u64) {
        terapipe::obs::set_enabled(traced);
        let cfg = TrainConfig {
            slicing: slicing.clone(),
            steps: obs_steps,
            trace: true,
            seed: 4,
            ..Default::default()
        };
        let mut t = Trainer::with_spec(spec.clone(), cfg).expect("trainer");
        let mut batcher = Batcher::new(&corpus, m.batch, m.seq_len, 4);
        let mut wall = f64::INFINITY;
        let mut allocs = u64::MAX;
        let mut spans = 0u64;
        for step in 0..obs_steps {
            let batches: Vec<_> = (0..1).map(|_| batcher.next_batch()).collect();
            let before = ALLOCS.load(Ordering::SeqCst);
            let (res, ms) = time_ms(|| t.step(&batches));
            let delta = ALLOCS.load(Ordering::SeqCst) - before;
            res.expect("obs bench step");
            // drain outside the timed/counted region; also keeps the
            // fixed-capacity buffers from overflowing across reps
            spans += terapipe::obs::flush().spans.len() as u64;
            if step == 0 {
                continue; // warmup: thread spin-up + recorder slot claims
            }
            wall = wall.min(ms);
            allocs = allocs.min(delta);
        }
        drop(t);
        terapipe::obs::set_enabled(false);
        (wall, allocs, spans / obs_steps as u64)
    };
    let (untraced_ms, untraced_allocs, _) = obs_run(false);
    let (traced_ms, traced_allocs, spans_per_step) = obs_run(true);
    let overhead = (traced_ms - untraced_ms) / untraced_ms.max(1e-9);
    let extra_allocs = traced_allocs.saturating_sub(untraced_allocs);
    println!("\n## obs: span recorder overhead (pipelined step, min of {reps})");
    println!(
        "untraced {untraced_ms:.2} ms, traced {traced_ms:.2} ms ({:+.2}%), ~{spans_per_step} spans/step",
        100.0 * overhead
    );
    println!("recorder-attributable steady-state allocations: {extra_allocs}");

    // ---- obs: flight recorder on top of the traced step ----
    // Same traced schedule; the flight leg additionally drains the span
    // buffer into a small StepFrame ring each step (the black-box
    // recorder's steady-state duty cycle). Ring slots are pre-allocated
    // and reused via clear()+extend, so once every slot has been filled
    // once, a record_step is a pure copy: its allocation count — measured
    // directly around the call, while the worker threads are parked
    // between steps — must be zero, and its wall cost ≤ 1% of the step.
    let flight_ring: usize = 2;
    let flight_run = |record: bool| -> (f64, u64) {
        terapipe::obs::set_enabled(true);
        let cfg = TrainConfig {
            slicing: slicing.clone(),
            steps: obs_steps,
            trace: true,
            seed: 4,
            ..Default::default()
        };
        let mut t = Trainer::with_spec(spec.clone(), cfg).expect("trainer");
        let mut batcher = Batcher::new(&corpus, m.batch, m.seq_len, 4);
        let mut flight = FlightRecorder::new(flight_ring);
        flight.set_fingerprint(plan_fingerprint(&slicing, &[4]));
        let health = vec![0u8; m.num_stages];
        let mut wall = f64::INFINITY;
        let mut ring_allocs = u64::MAX;
        for step in 0..obs_steps {
            let batches: Vec<_> = (0..1).map(|_| batcher.next_batch()).collect();
            let (res, ms) = time_ms(|| {
                let r = t.step(&batches);
                let f = terapipe::obs::flush();
                if record {
                    let before = ALLOCS.load(Ordering::SeqCst);
                    flight.record_step(step as u64 + 1, 0.0, 0.0, &f.spans, f.dropped, &health, &[]);
                    let delta = ALLOCS.load(Ordering::SeqCst) - before;
                    if step >= flight_ring {
                        // every slot filled once: steady state
                        ring_allocs = ring_allocs.min(delta);
                    }
                }
                r
            });
            res.expect("flight bench step");
            if step == 0 {
                continue; // warmup: thread spin-up + recorder slot claims
            }
            wall = wall.min(ms);
        }
        drop(t);
        terapipe::obs::set_enabled(false);
        (wall, if record { ring_allocs } else { 0 })
    };
    let (noflight_ms, _) = flight_run(false);
    let (flight_ms, ring_allocs_min) = flight_run(true);
    let flight_overhead = (flight_ms - noflight_ms) / noflight_ms.max(1e-9);
    println!("\n## obs: flight recorder overhead (ring of {flight_ring}, min of {reps})");
    println!(
        "no-flight {noflight_ms:.2} ms, flight {flight_ms:.2} ms ({:+.2}%)",
        100.0 * flight_overhead
    );
    println!("ring steady-state allocations per record_step: {ring_allocs_min}");

    let obs_report = Json::obj(vec![
        ("bench", Json::Str("obs".into())),
        ("quick", Json::Num(if quick { 1.0 } else { 0.0 })),
        ("untraced_ms_min", Json::Num(untraced_ms)),
        ("traced_ms_min", Json::Num(traced_ms)),
        ("overhead_frac", Json::Num(overhead)),
        ("spans_per_step", Json::Num(spans_per_step as f64)),
        ("untraced_step_allocs_min", Json::Num(untraced_allocs as f64)),
        ("traced_step_allocs_min", Json::Num(traced_allocs as f64)),
        ("recorder_extra_allocs_min", Json::Num(extra_allocs as f64)),
        ("noflight_ms_min", Json::Num(noflight_ms)),
        ("flight_ms_min", Json::Num(flight_ms)),
        ("flight_overhead_frac", Json::Num(flight_overhead)),
        ("flight_ring_steps", Json::Num(flight_ring as f64)),
        ("flight_ring_allocs_min", Json::Num(ring_allocs_min as f64)),
    ]);
    let obs_path = std::env::var("CARGO_MANIFEST_DIR")
        .map(|d| format!("{d}/../BENCH_obs.json"))
        .unwrap_or_else(|_| "BENCH_obs.json".into());
    std::fs::write(&obs_path, obs_report.to_string() + "\n").expect("write BENCH_obs.json");
    println!("wrote {obs_path}");
    if !quick {
        assert!(
            overhead <= 0.03,
            "recorder overhead {:.2}% exceeds the 3% budget ({traced_ms:.2} vs {untraced_ms:.2} ms)",
            100.0 * overhead
        );
        assert_eq!(
            extra_allocs, 0,
            "recorder must be allocation-free at steady state \
             (traced {traced_allocs} vs untraced {untraced_allocs} allocs/step)"
        );
        assert!(
            flight_overhead <= 0.01,
            "flight recorder overhead {:.2}% exceeds the 1% budget \
             ({flight_ms:.2} vs {noflight_ms:.2} ms)",
            100.0 * flight_overhead
        );
        assert_eq!(
            ring_allocs_min, 0,
            "flight ring must be allocation-free once every slot is warm \
             (min {ring_allocs_min} allocs per record_step)"
        );
    }
}
