//! Bench: the native CPU stage backend — the execution engine behind
//! `terapipe train`/`measure` in the default build. Emits a
//! machine-readable `BENCH_exec.json` at the workspace root (same
//! protocol as `BENCH_sim.json` / `BENCH_dp_solver.json`).
//!
//! Measured:
//!
//! * per-bucket cell latency: `stage_fwd` alone and `stage_fwd +
//!   stage_bwd` (the `CostModel` unit) at empty and near-full context —
//!   the real-execution analogue of Fig. 3's latency-vs-tokens curve;
//! * one full pipelined training step through the threaded coordinator
//!   vs *serial* execution of the same slices (the sum of every traced
//!   per-slice fwd/bwd time across all stages) — how much of the
//!   schedule's overlap survives on this machine.
//!
//! `--quick` runs a reduced model with few reps and no sanity gate — the
//! CI bench-smoke job uses it to catch compile errors and
//! order-of-magnitude blowups without full bench runtimes.

use terapipe::backend::{BackendSpec, NativeSpec, StageBackend};
use terapipe::coordinator::{TrainConfig, Trainer};
use terapipe::data::{synthetic_corpus, Batcher};
use terapipe::runtime::manifest::ModelDims;
use terapipe::runtime::tensor::HostTensor;
use terapipe::util::json::Json;
use terapipe::util::{time_ms, Stats};

fn bench_spec(quick: bool) -> NativeSpec {
    let (hidden, heads, layers, stages, seq_len, batch, gran) = if quick {
        (32, 4, 1, 2, 64, 2, 16)
    } else {
        (128, 8, 2, 4, 256, 4, 32)
    };
    NativeSpec::new(
        ModelDims {
            vocab: 256,
            hidden,
            num_heads: heads,
            layers_per_stage: layers,
            num_stages: stages,
            seq_len,
            batch,
            block_ctx: gran,
            seed: 42,
        },
        gran,
    )
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let reps = if quick { 2 } else { 5 };
    let spec = bench_spec(quick);
    let m = spec.model();
    let buckets = spec.buckets();
    println!(
        "# native exec backend (H={}, NH={}, NL={}, K={}, L={}, B={}, reps={reps}{})",
        m.hidden,
        m.num_heads,
        m.layers_per_stage,
        m.num_stages,
        m.seq_len,
        m.batch,
        if quick { ", --quick" } else { "" }
    );

    // ---- per-bucket cell latency (middle stage, like `measure`) ----
    let mut be = spec
        .build(1 % m.num_stages, m.num_stages, None)
        .expect("build bench backend");
    let kv = HostTensor::zeros_f32(&m.kv_shape());
    let mut bucket_rows: Vec<Json> = Vec::new();
    println!("\n## per-bucket stage latency (ms, mean ± std)");
    println!("| i (slice) | j (ctx) | fwd | fwd+bwd |");
    for &i in &buckets {
        // empty and near-full context — one point when they coincide (i = L)
        let both = [0usize, m.seq_len - i];
        let ctxs = if i == m.seq_len { &both[..1] } else { &both[..] };
        for &j in ctxs {
            let h = HostTensor::zeros_f32(&[m.batch, i, m.hidden]);
            let g_h = HostTensor::zeros_f32(&[m.batch, i, m.hidden]);
            let g_kv = HostTensor::zeros_f32(&m.kv_new_shape(i));
            let fwd: Vec<f64> = (0..reps)
                .map(|_| time_ms(|| be.stage_fwd(&h, &kv, &kv, j).unwrap()).1)
                .collect();
            let both: Vec<f64> = (0..reps)
                .map(|_| {
                    time_ms(|| {
                        be.stage_fwd(&h, &kv, &kv, j).unwrap();
                        be.stage_bwd(&h, &kv, &kv, j, &g_h, &g_kv, &g_kv).unwrap();
                    })
                    .1
                })
                .collect();
            let fs = Stats::from_samples(&fwd);
            let bs = Stats::from_samples(&both);
            println!("| {i} | {j} | {} | {} |", fs.pm(), bs.pm());
            bucket_rows.push(Json::obj(vec![
                ("i", Json::Num(i as f64)),
                ("j", Json::Num(j as f64)),
                ("fwd_ms_mean", Json::Num(fs.mean)),
                ("fwd_ms_min", Json::Num(fs.min)),
                ("fwd_bwd_ms_mean", Json::Num(bs.mean)),
                ("fwd_bwd_ms_min", Json::Num(bs.min)),
            ]));
        }
    }
    drop(be);

    // ---- pipelined step vs serial execution of the same slices ----
    let slice_len = spec.buckets()[0];
    let slicing = vec![slice_len; m.seq_len / slice_len];
    let steps = 1 + reps; // step 0 is warmup
    let cfg = TrainConfig {
        slicing: slicing.clone(),
        steps,
        trace: true,
        seed: 4,
        ..Default::default()
    };
    let mut t = Trainer::with_spec(spec.clone(), cfg).expect("trainer");
    let corpus = synthetic_corpus(1 << 14, 7);
    let mut batcher = Batcher::new(&corpus, m.batch, m.seq_len, 4);
    let mut pipelined = Vec::new();
    let mut serial = Vec::new();
    for step in 0..steps {
        let batches: Vec<_> = (0..1).map(|_| batcher.next_batch()).collect();
        let (res, wall_ms) = time_ms(|| t.step(step, &batches));
        res.expect("bench step");
        if step == 0 {
            continue; // warmup: cold caches, lazy thread spin-up
        }
        // serial baseline: the same slices' traced fwd+bwd times summed
        // across all stages — what a one-thread, no-overlap execution of
        // this step's compute would cost
        let busy: f64 = t.last_timings().iter().map(|s| s.ms).sum();
        serial.push(busy);
        pipelined.push(wall_ms);
    }
    let ss = Stats::from_samples(&serial);
    let ps = Stats::from_samples(&pipelined);
    let speedup = ss.min / ps.min.max(1e-9);
    println!("\n## pipelined step vs serial slice execution ({} stages × {} slices)", m.num_stages, slicing.len());
    println!("serial (Σ traced slice fwd+bwd): {} ms (min {:.2})", ss.pm(), ss.min);
    println!("pipelined step wall:             {} ms (min {:.2})", ps.pm(), ps.min);
    println!("overlap speedup: {speedup:.2}x on {} worker threads", m.num_stages);

    // ---- machine-readable report (workspace root) ----
    let report = Json::obj(vec![
        ("bench", Json::Str("exec".into())),
        ("quick", Json::Num(if quick { 1.0 } else { 0.0 })),
        ("reps", Json::Num(reps as f64)),
        (
            "model",
            Json::obj(vec![
                ("hidden", Json::Num(m.hidden as f64)),
                ("heads", Json::Num(m.num_heads as f64)),
                ("layers_per_stage", Json::Num(m.layers_per_stage as f64)),
                ("stages", Json::Num(m.num_stages as f64)),
                ("seq_len", Json::Num(m.seq_len as f64)),
                ("batch", Json::Num(m.batch as f64)),
            ]),
        ),
        ("per_bucket", Json::arr(bucket_rows)),
        (
            "step",
            Json::obj(vec![
                ("slices", Json::Num(slicing.len() as f64)),
                ("serial_ms_min", Json::Num(ss.min)),
                ("serial_ms_mean", Json::Num(ss.mean)),
                ("pipelined_ms_min", Json::Num(ps.min)),
                ("pipelined_ms_mean", Json::Num(ps.mean)),
                ("overlap_speedup_min_over_min", Json::Num(speedup)),
            ]),
        ),
    ]);
    let path = std::env::var("CARGO_MANIFEST_DIR")
        .map(|d| format!("{d}/../BENCH_exec.json"))
        .unwrap_or_else(|_| "BENCH_exec.json".into());
    std::fs::write(&path, report.to_string() + "\n").expect("write BENCH_exec.json");
    println!("\nwrote {path}");

    // Sanity gate (skipped in --quick): overlapped execution must not be
    // pathologically slower than running the same slices serially. The
    // bound is loose — on few-core boxes the stage threads contend with
    // the kernels' own rayon parallelism — it exists to catch schedule
    // regressions (a serialized pipeline, a lost wakeup), not to promise
    // a speedup.
    if !quick {
        assert!(
            speedup > 0.5,
            "pipelined step is >2x slower than serial slice execution ({speedup:.2}x)"
        );
    }
}
