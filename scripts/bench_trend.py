#!/usr/bin/env python3
"""Merge every ``BENCH_*.json`` bench report into one trend snapshot.

Each Rust bench (``cargo bench --bench exec`` etc.) writes a
machine-readable ``BENCH_<name>.json`` at the workspace root: a flat
object with a ``"bench"`` tag, scalar gate metrics, and (for some
benches) nested row arrays. This script gathers the scalar metrics from
all of them into a single table so a run's headline numbers live in one
place, and optionally diffs against an earlier snapshot to show drift —
the poor man's continuous-benchmarking dashboard.

Usage::

    python scripts/bench_trend.py                 # scan repo root, print table
    python scripts/bench_trend.py --out BENCH_trend.json
    python scripts/bench_trend.py --baseline old_trend.json   # show deltas
    python scripts/bench_trend.py --dir path/to/reports

Only the standard library is used. Nested arrays/objects inside a bench
report (per-shape rows and the like) are skipped — the trend table is
for headline scalars; the per-bench files keep the detail.
"""

import argparse
import glob
import json
import os
import sys


def load_reports(root):
    """Return {bench_name: {metric: scalar}} for every BENCH_*.json."""
    merged = {}
    for path in sorted(glob.glob(os.path.join(root, "BENCH_*.json"))):
        try:
            with open(path) as f:
                report = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: skipping {path}: {e}", file=sys.stderr)
            continue
        if not isinstance(report, dict):
            print(f"warning: skipping {path}: not an object", file=sys.stderr)
            continue
        name = report.get("bench")
        if not isinstance(name, str):
            # fall back to the filename stem: BENCH_<name>.json
            name = os.path.basename(path)[len("BENCH_"):-len(".json")]
        scalars = {
            k: v
            for k, v in report.items()
            if k != "bench" and isinstance(v, (int, float)) and not isinstance(v, bool)
        }
        merged[name] = scalars
    return merged


def fmt_num(v):
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return f"{v:.6g}"


def print_table(merged, baseline=None):
    rows = []
    for bench in sorted(merged):
        for metric in sorted(merged[bench]):
            cur = merged[bench][metric]
            delta = ""
            if baseline is not None:
                old = baseline.get(bench, {}).get(metric)
                if isinstance(old, (int, float)) and old:
                    delta = f"{100.0 * (cur - old) / abs(old):+.1f}%"
                elif old is not None:
                    delta = "new-base" if old == 0 and cur else ""
                else:
                    delta = "new"
            rows.append((bench, metric, fmt_num(cur), delta))
    if not rows:
        print("no BENCH_*.json reports found")
        return
    widths = [max(len(r[i]) for r in rows + [("bench", "metric", "value", "vs base")])
              for i in range(4)]
    header = ("bench", "metric", "value", "vs base" if baseline is not None else "")
    line = "  ".join(h.ljust(w) for h, w in zip(header, widths)).rstrip()
    print(line)
    print("-" * len(line))
    last_bench = None
    for bench, metric, value, delta in rows:
        shown = bench if bench != last_bench else ""
        last_bench = bench
        print("  ".join(c.ljust(w) for c, w in
                        zip((shown, metric, value, delta), widths)).rstrip())


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dir", default=None,
                    help="directory holding BENCH_*.json (default: repo root)")
    ap.add_argument("--out", default=None,
                    help="write the merged snapshot to this JSON file")
    ap.add_argument("--baseline", default=None,
                    help="earlier merged snapshot to diff against")
    args = ap.parse_args()

    root = args.dir or os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    merged = load_reports(root)

    baseline = None
    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)

    print_table(merged, baseline)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(merged, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"\nwrote {args.out}")

    return 0


if __name__ == "__main__":
    sys.exit(main())
