"""Pure-jnp correctness oracles for the TeraPipe compute path.

These are the ground truth against which (a) the Pallas slice-attention
kernel and (b) the AOT-lowered stage executables are validated. Everything
here is written in the most obvious possible jnp, with no tiling, masking
tricks, or numerical shortcuts beyond a numerically-stable softmax.

Conventions (shared with model.py and the rust coordinator):
  * A *slice* is `s` consecutive token positions of one training sequence
    (the paper's `s_i`, Sec 3.2).
  * The *context* is the `ctx_len` positions strictly before the slice.
  * K/V buffers are padded to a fixed `L_max` so all HLO shapes are static;
    positions `>= ctx_len + s` in the buffer are padding and must not
    influence the result (tested).
"""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal_offset: int = 0):
    """Plain softmax attention with a causal mask.

    q: [S, D] queries for global positions [causal_offset, causal_offset+S).
    k, v: [T, D] keys/values for global positions [0, T).
    Query i may attend to key j iff j <= causal_offset + i.
    """
    s, d = q.shape
    t = k.shape[0]
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    q_pos = causal_offset + jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    mask = k_pos <= q_pos
    scores = jnp.where(mask, scores, -jnp.inf)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    probs = jnp.exp(scores)
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return probs @ v


def slice_attention_ref(q, k_buf, v_buf, ctx_len):
    """Oracle for the Pallas slice-attention kernel.

    q:            [S, D]   queries of the current slice.
    k_buf, v_buf: [T, D]   padded buffer; [0, ctx_len) is real context,
                           [ctx_len, ctx_len+S) holds this slice's keys,
                           the rest is padding.
    Query i (global position ctx_len+i) attends to buffer positions
    j <= ctx_len + i.  `ctx_len` may be a python int or a traced scalar.
    """
    s, d = q.shape
    t = k_buf.shape[0]
    scores = (q @ k_buf.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    q_pos = ctx_len + jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    mask = k_pos <= q_pos
    scores = jnp.where(mask, scores, -jnp.inf)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    probs = jnp.exp(scores)
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return probs @ v_buf


def mha_slice_ref(q, k_buf, v_buf, ctx_len):
    """Multi-head version. q: [S, NH, HD]; k_buf, v_buf: [T, NH, HD]."""
    s, nh, hd = q.shape
    outs = [
        slice_attention_ref(q[:, h, :], k_buf[:, h, :], v_buf[:, h, :], ctx_len)
        for h in range(nh)
    ]
    return jnp.stack(outs, axis=1)


def layer_norm_ref(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def gelu_ref(x):
    # tanh approximation, matching model.py
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))


def softmax_xent_ref(logits, targets):
    """Sum (not mean) of token cross-entropies. logits [N, V], targets [N]."""
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    logz = jnp.log(jnp.sum(jnp.exp(logits), axis=-1))
    gold = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return jnp.sum(logz - gold)
