"""L1 — Pallas slice-attention kernel (the TeraPipe compute hot-spot).

TeraPipe's unit of pipelined work is a *token slice*: `S` consecutive
positions of one sequence, attending causally to (a) the `ctx_len` tokens
produced by earlier slices on the same stage and (b) themselves. This
kernel computes exactly that — softmax attention of a resident Q block
against a padded K/V buffer — as a flash-attention-style streaming kernel.

Hardware adaptation (paper targets V100 threadblocks — DESIGN.md §3):
  * The slice's Q block (S × D) stays resident in VMEM for the whole
    kernel; context K/V stream through in `block_ctx`-sized tiles via
    `BlockSpec` index maps — the HBM↔VMEM schedule that replaces the GPU
    threadblock loop over the context.
  * An online running-max / running-denominator accumulation keeps VMEM at
    O(S·(block_ctx + D)) instead of O(S·L).
  * The S×D·block_ctx matmuls are the MXU-shaped inner loop; on a real TPU
    S, D, block_ctx would be padded to multiples of the 128×128 systolic
    array (see DESIGN.md §Perf for the VMEM/MXU estimate).

The kernel MUST run with interpret=True here: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. Numerics are
validated against `ref.slice_attention_ref` by pytest (hypothesis sweep
over shapes) — that is the correctness signal; interpret-mode wallclock is
meaningless and never used.

Buffer layout (shared with model.py / the rust coordinator):
  k_buf/v_buf have length T >= ctx_len + S. [0, ctx_len) is real context,
  [ctx_len, ctx_len + S) is this slice's own K/V (already scattered in by
  the caller), and everything after is padding. Query i sits at global
  position ctx_len + i and may attend to buffer positions j <= ctx_len + i,
  which simultaneously enforces causality within the slice and excludes
  the padding tail.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _slice_attn_kernel(ctx_len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, block_ctx: int, num_ctx_blocks: int):
    """Grid = (num_heads, num_ctx_blocks); the ctx-block axis is sequential.

    o_ref accumulates the *unnormalized* weighted sum across ctx blocks;
    m_ref / l_ref hold the running row max and softmax denominator. On the
    final ctx block, o_ref is normalized in place.
    """
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]  # [S, D] — resident across all ctx blocks of this head
    k = k_ref[0]  # [block_ctx, D] — the streamed tile
    v = v_ref[0]  # [block_ctx, D]
    s, d = q.shape

    scale = jax.lax.rsqrt(jnp.asarray(d, jnp.float32))
    scores = (q @ k.T) * scale  # [S, block_ctx] — MXU-shaped

    # Causal + padding mask: query i is global position ctx_len + i; this
    # tile covers buffer positions [kb*block_ctx, (kb+1)*block_ctx).
    ctx_len = ctx_len_ref[0]
    q_pos = ctx_len + jax.lax.broadcasted_iota(jnp.int32, (s, block_ctx), 0)
    k_pos = kb * block_ctx + jax.lax.broadcasted_iota(jnp.int32, (s, block_ctx), 1)
    mask = k_pos <= q_pos

    m_prev = m_ref[0]  # [S]
    l_prev = l_ref[0]
    acc_prev = o_ref[0]

    block_max = jnp.max(jnp.where(mask, scores, NEG_INF), axis=-1)  # [S]
    m_new = jnp.maximum(m_prev, block_max)
    # `mask` multiplies probabilities directly so a fully-masked tile
    # contributes exactly zero (exp(NEG_INF - m) underflow is not relied on).
    p = jnp.where(mask, jnp.exp(scores - m_new[:, None]), 0.0)  # [S, block_ctx]
    alpha = jnp.exp(m_prev - m_new)  # [S]
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc_new = acc_prev * alpha[:, None] + p @ v

    m_ref[0] = m_new
    l_ref[0] = l_new
    o_ref[0] = acc_new

    @pl.when(kb == num_ctx_blocks - 1)
    def _finalize():
        # Every query row has at least one valid key (itself), so l > 0.
        o_ref[0] = o_ref[0] / l_ref[0][:, None]


def _slice_attention_dense(q, k_buf, v_buf, ctx_len):
    """Dense jnp formulation (all heads at once). Used only to derive the
    backward pass of the custom_vjp below; forward runs the Pallas kernel."""
    s, nh, d = q.shape
    t = k_buf.shape[0]
    scores = jnp.einsum("snd,tnd->nst", q, k_buf) / jnp.sqrt(jnp.asarray(d, q.dtype))
    q_pos = ctx_len + jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    mask = (k_pos <= q_pos)[None, :, :]
    scores = jnp.where(mask, scores, NEG_INF)
    scores = scores - jax.lax.stop_gradient(jnp.max(scores, axis=-1, keepdims=True))
    probs = jnp.exp(scores)
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("nst,tnd->snd", probs, v_buf)


def _slice_attention_fwd_impl(q, k_buf, v_buf, ctx_len, block_ctx: int):
    s, nh, d = q.shape
    t = k_buf.shape[0]
    bc = min(block_ctx, t)
    if t % bc != 0:
        raise ValueError(f"buffer length {t} not divisible by block_ctx {bc}")
    num_ctx_blocks = t // bc

    # Head-major layout so the grid's leading axis walks heads.
    qh = jnp.transpose(q, (1, 0, 2))  # [NH, S, D]
    kh = jnp.transpose(k_buf, (1, 0, 2))  # [NH, T, D]
    vh = jnp.transpose(v_buf, (1, 0, 2))
    ctx = jnp.reshape(jnp.asarray(ctx_len, jnp.int32), (1,))

    kernel = functools.partial(
        _slice_attn_kernel, block_ctx=bc, num_ctx_blocks=num_ctx_blocks
    )
    out, _m, _l = pl.pallas_call(
        kernel,
        grid=(nh, num_ctx_blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda h, kb: (0,)),  # ctx_len: broadcast
            pl.BlockSpec((1, s, d), lambda h, kb: (h, 0, 0)),  # q: resident
            pl.BlockSpec((1, bc, d), lambda h, kb: (h, kb, 0)),  # k tile
            pl.BlockSpec((1, bc, d), lambda h, kb: (h, kb, 0)),  # v tile
        ],
        out_specs=[
            pl.BlockSpec((1, s, d), lambda h, kb: (h, 0, 0)),  # o: revisited
            pl.BlockSpec((1, s), lambda h, kb: (h, 0)),  # running max
            pl.BlockSpec((1, s), lambda h, kb: (h, 0)),  # running denom
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nh, s, d), jnp.float32),
            jax.ShapeDtypeStruct((nh, s), jnp.float32),
            jax.ShapeDtypeStruct((nh, s), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(ctx, qh, kh, vh)
    return jnp.transpose(out, (1, 0, 2))  # back to [S, NH, D]


# pallas_call is not differentiable (even under interpret=True), so the
# kernel is paired with an analytic backward derived from the dense jnp
# formulation — the standard flash-attention custom_vjp pattern. Both paths
# are validated against ref.py by pytest.
@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _slice_attention_cvjp(q, k_buf, v_buf, ctx_len, block_ctx):
    return _slice_attention_fwd_impl(q, k_buf, v_buf, ctx_len, block_ctx)


def _cvjp_fwd(q, k_buf, v_buf, ctx_len, block_ctx):
    out = _slice_attention_fwd_impl(q, k_buf, v_buf, ctx_len, block_ctx)
    return out, (q, k_buf, v_buf, ctx_len)


def _cvjp_bwd(block_ctx, res, g):
    import numpy as np

    q, k_buf, v_buf, ctx_len = res
    _, vjp = jax.vjp(_slice_attention_dense, q, k_buf, v_buf, ctx_len)
    gq, gk, gv, _ = vjp(g)
    # integer primal → float0 cotangent
    g_ctx = np.zeros(np.shape(ctx_len), jax.dtypes.float0)
    return gq, gk, gv, g_ctx


_slice_attention_cvjp.defvjp(_cvjp_fwd, _cvjp_bwd)


def slice_attention(q, k_buf, v_buf, ctx_len, *, block_ctx: int = 64):
    """Flash-style causal slice attention (single sequence).

    Args:
      q:            [S, NH, D] float32 — queries of the current slice.
      k_buf, v_buf: [T, NH, D] float32 — padded K/V buffer (see module doc).
      ctx_len:      scalar int32 (may be traced) — #real context positions.
      block_ctx:    K/V tile length streamed per grid step; must divide T.

    Returns: [S, NH, D] float32 attention output. Differentiable in
    q/k_buf/v_buf via the custom VJP above.
    """
    return _slice_attention_cvjp(q, k_buf, v_buf, jnp.asarray(ctx_len, jnp.int32), block_ctx)


def slice_attention_batched(q, k_buf, v_buf, ctx_len, *, block_ctx: int = 64):
    """vmap over a leading batch axis. q: [B, S, NH, D]; bufs [B, T, NH, D]."""
    fn = functools.partial(slice_attention, block_ctx=block_ctx)
    return jax.vmap(fn, in_axes=(0, 0, 0, None))(q, k_buf, v_buf, ctx_len)


def vmem_estimate_bytes(s: int, d: int, block_ctx: int) -> int:
    """Static VMEM footprint estimate for DESIGN.md §Perf (fp32 bytes).

    Resident: Q (S·D), K/V tile (2·block_ctx·D), scores/p (S·block_ctx),
    accumulator (S·D), running stats (2·S).
    """
    floats = s * d + 2 * block_ctx * d + s * block_ctx + s * d + 2 * s
    return 4 * floats


def mxu_utilization_estimate(s: int, d: int, block_ctx: int) -> float:
    """Fraction of each 128×128 MXU tile doing useful work, per matmul.

    Both inner matmuls are (S×D)·(D×block_ctx) and (S×block_ctx)·(block_ctx×D);
    utilization is the product of per-axis fill ratios against 128 tiles.
    """

    def fill(n: int) -> float:
        pad = ((n + 127) // 128) * 128
        return n / pad

    return min(fill(s) * fill(d), fill(s) * fill(block_ctx))
