"""L2 — GPT pipeline-stage model in JAX (build-time only).

TeraPipe partitions a Transformer LM F = c_K ∘ … ∘ c_1 into *cells*, one
per pipeline stage, and pipelines *token slices* of each training sequence
through the cells (paper §3.2). This module defines the per-cell compute as
pure JAX functions of explicit flat parameter tuples, shaped so that
`aot.py` can lower each one to a static-shape HLO module the rust
coordinator executes via PJRT:

  embed_fwd / embed_bwd   token+position embedding (first stage only)
  stage_fwd / stage_bwd   `layers_per_stage` pre-LN GPT blocks over one
                          token slice, reading/extending a padded KV
                          context buffer (the paper's "hidden states of
                          previous positions")
  head_fwd / head_bwd     final LN + LM head + summed token cross-entropy
                          (last stage only)
  adam_step               fused Adam update for any parameter tuple

Backward executables recompute the forward internally (rematerialization —
paper §3.4 "combine with memory optimization") via `jax.vjp`, so the rust
side only stores each slice's *input* activation, context lengths, and the
grown KV buffers — never python-side residuals. Crucially, `stage_bwd`
returns gradients w.r.t. the KV *context* as well: those are attention
gradients flowing from this slice back to *earlier* slices of the same
sequence, which the coordinator accumulates and feeds into the earlier
slices' `g_know/g_vnew` cotangents (reverse token order), exactly mirroring
the fine-grained dependency structure that makes token-level pipelining
valid in the first place.

All shapes are static except scalar operands (`ctx_len`, `pos_offset`,
`step`, `lr`); the KV buffer is padded to the full sequence length T = L.
Parameters are flat tuples in the canonical orders given by
`*_param_specs()` — the manifest written by aot.py records the same order
for the rust side.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.slice_attention import slice_attention_batched

LAYER_PARAM_NAMES = (
    "ln1_g", "ln1_b", "w_qkv", "b_qkv", "w_proj", "b_proj",
    "ln2_g", "ln2_b", "w_fc1", "b_fc1", "w_fc2", "b_fc2",
)
PARAMS_PER_LAYER = len(LAYER_PARAM_NAMES)


class ModelDims(NamedTuple):
    """Static model/stage geometry shared by all executables."""

    vocab: int
    hidden: int
    num_heads: int
    layers_per_stage: int
    num_stages: int
    seq_len: int  # T = L: KV buffers are padded to this
    batch: int  # sequences per microbatch (each fully token-sliced)
    block_ctx: int  # L1 kernel KV tile length

    @property
    def head_dim(self) -> int:
        return self.hidden // self.num_heads

    @property
    def ffn(self) -> int:
        return 4 * self.hidden

    @property
    def num_layers(self) -> int:
        return self.layers_per_stage * self.num_stages


# ---------------------------------------------------------------------------
# Parameter specs (canonical flat order — mirrored in artifacts/manifest.json)
# ---------------------------------------------------------------------------


def layer_param_shapes(d: ModelDims):
    h, f = d.hidden, d.ffn
    return {
        "ln1_g": (h,), "ln1_b": (h,),
        "w_qkv": (h, 3 * h), "b_qkv": (3 * h,),
        "w_proj": (h, h), "b_proj": (h,),
        "ln2_g": (h,), "ln2_b": (h,),
        "w_fc1": (h, f), "b_fc1": (f,),
        "w_fc2": (f, h), "b_fc2": (h,),
    }


def stage_param_specs(d: ModelDims):
    """[(name, shape)] for one stage: layers_per_stage × 12 arrays."""
    shapes = layer_param_shapes(d)
    return [
        (f"layer{i}.{n}", shapes[n])
        for i in range(d.layers_per_stage)
        for n in LAYER_PARAM_NAMES
    ]


def embed_param_specs(d: ModelDims):
    return [("tok_emb", (d.vocab, d.hidden)), ("pos_emb", (d.seq_len, d.hidden))]


def head_param_specs(d: ModelDims):
    return [
        ("lnf_g", (d.hidden,)), ("lnf_b", (d.hidden,)),
        ("w_out", (d.hidden, d.vocab)), ("b_out", (d.vocab,)),
    ]


def init_params(d: ModelDims, seed: int = 0):
    """Deterministic GPT-2-style init. Returns (embed, stages, head) where
    stages is a list (one flat tuple per stage)."""
    key = jax.random.PRNGKey(seed)

    def normal(key, shape, std):
        return (std * jax.random.normal(key, shape)).astype(jnp.float32)

    k_embed, k_head, *k_stages = jax.random.split(key, 2 + d.num_stages)

    ke1, ke2 = jax.random.split(k_embed)
    embed = (normal(ke1, (d.vocab, d.hidden), 0.02),
             normal(ke2, (d.seq_len, d.hidden), 0.01))

    # residual-scaled init for projections back onto the residual stream
    resid_std = 0.02 / (2.0 * d.num_layers) ** 0.5
    stages = []
    for ks in k_stages:
        arrays = []
        for i, kl in enumerate(jax.random.split(ks, d.layers_per_stage)):
            kq, kp, k1, k2 = jax.random.split(kl, 4)
            shapes = layer_param_shapes(d)
            vals = {
                "ln1_g": jnp.ones(shapes["ln1_g"], jnp.float32),
                "ln1_b": jnp.zeros(shapes["ln1_b"], jnp.float32),
                "w_qkv": normal(kq, shapes["w_qkv"], 0.02),
                "b_qkv": jnp.zeros(shapes["b_qkv"], jnp.float32),
                "w_proj": normal(kp, shapes["w_proj"], resid_std),
                "b_proj": jnp.zeros(shapes["b_proj"], jnp.float32),
                "ln2_g": jnp.ones(shapes["ln2_g"], jnp.float32),
                "ln2_b": jnp.zeros(shapes["ln2_b"], jnp.float32),
                "w_fc1": normal(k1, shapes["w_fc1"], 0.02),
                "b_fc1": jnp.zeros(shapes["b_fc1"], jnp.float32),
                "w_fc2": normal(k2, shapes["w_fc2"], resid_std),
                "b_fc2": jnp.zeros(shapes["b_fc2"], jnp.float32),
            }
            arrays.extend(vals[n] for n in LAYER_PARAM_NAMES)
        stages.append(tuple(arrays))

    kh = jax.random.split(k_head, 1)[0]
    head = (jnp.ones((d.hidden,), jnp.float32), jnp.zeros((d.hidden,), jnp.float32),
            normal(kh, (d.hidden, d.vocab), 0.02), jnp.zeros((d.vocab,), jnp.float32))
    return embed, stages, head


# ---------------------------------------------------------------------------
# Forward compute
# ---------------------------------------------------------------------------


def layer_norm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))


def transformer_layer_slice(lp, h, k_ctx, v_ctx, ctx_len, d: ModelDims):
    """One pre-LN GPT block over a token slice.

    lp: 12-tuple in LAYER_PARAM_NAMES order.
    h: [B, S, H] slice hidden states; k_ctx/v_ctx: [B, T, NH, HD] padded
    buffers holding the context produced by earlier slices.
    Returns (h_out [B,S,H], k_slice [B,S,NH,HD], v_slice [B,S,NH,HD]).
    """
    (ln1_g, ln1_b, w_qkv, b_qkv, w_proj, b_proj,
     ln2_g, ln2_b, w_fc1, b_fc1, w_fc2, b_fc2) = lp
    b, s, hidden = h.shape
    nh, hd = d.num_heads, d.head_dim

    x = layer_norm(h, ln1_g, ln1_b)
    qkv = x @ w_qkv + b_qkv
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, nh, hd)
    k_slice = k.reshape(b, s, nh, hd)
    v_slice = v.reshape(b, s, nh, hd)

    # Scatter this slice's K/V into the padded buffer at ctx_len; the L1
    # kernel's causal mask then covers both context and within-slice terms.
    zero = jnp.zeros((), jnp.int32)
    k_buf = jax.lax.dynamic_update_slice(k_ctx, k_slice, (zero, ctx_len, zero, zero))
    v_buf = jax.lax.dynamic_update_slice(v_ctx, v_slice, (zero, ctx_len, zero, zero))

    att = slice_attention_batched(q, k_buf, v_buf, ctx_len, block_ctx=d.block_ctx)
    att = att.reshape(b, s, hidden)
    h = h + att @ w_proj + b_proj

    x = layer_norm(h, ln2_g, ln2_b)
    h = h + gelu(x @ w_fc1 + b_fc1) @ w_fc2 + b_fc2
    return h, k_slice, v_slice


def stage_fwd(params, h, k_ctx, v_ctx, ctx_len, d: ModelDims):
    """One pipeline cell over one token slice.

    params: flat tuple per stage_param_specs.
    h: [B, S, H]; k_ctx/v_ctx: [NL, B, T, NH, HD] (NL = layers_per_stage).
    Returns (h_out, k_new [NL,B,S,NH,HD], v_new [NL,B,S,NH,HD]).
    """
    k_news, v_news = [], []
    for i in range(d.layers_per_stage):
        lp = params[i * PARAMS_PER_LAYER : (i + 1) * PARAMS_PER_LAYER]
        h, k_s, v_s = transformer_layer_slice(lp, h, k_ctx[i], v_ctx[i], ctx_len, d)
        k_news.append(k_s)
        v_news.append(v_s)
    return h, jnp.stack(k_news), jnp.stack(v_news)


def embed_fwd(params, tokens, pos_offset, d: ModelDims):
    """tokens [B, S] int32, pos_offset scalar → h [B, S, H]."""
    tok_emb, pos_emb = params
    s = tokens.shape[1]
    pos = jax.lax.dynamic_slice(pos_emb, (pos_offset, jnp.zeros((), jnp.int32)), (s, d.hidden))
    return tok_emb[tokens] + pos[None, :, :]


def head_fwd(params, h, targets, d: ModelDims):
    """Final LN + LM head + summed cross-entropy over the slice.

    h [B,S,H], targets [B,S] int32 → scalar loss (sum over B·S tokens;
    the coordinator normalizes by B·L at the end of the minibatch).
    """
    lnf_g, lnf_b, w_out, b_out = params
    x = layer_norm(h, lnf_g, lnf_b)
    logits = x @ w_out + b_out  # [B, S, V]
    logits = logits - jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    logz = jnp.log(jnp.sum(jnp.exp(logits), axis=-1))  # [B, S]
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(logz - gold)


# ---------------------------------------------------------------------------
# Backward compute (recompute-based VJPs — see module docstring)
# ---------------------------------------------------------------------------


def stage_bwd(params, h, k_ctx, v_ctx, ctx_len, g_hout, g_knew, g_vnew, d: ModelDims):
    """VJP of stage_fwd for one slice.

    g_hout: upstream grad from the next stage for this slice.
    g_knew/g_vnew: accumulated attention grads w.r.t. this slice's own K/V,
    contributed by *later* slices of the same sequence (zero for the last).
    Returns (g_params…, g_h, g_kctx, g_vctx); g_kctx/g_vctx feed the
    coordinator's per-stage context-grad accumulators.
    """
    fn = lambda p, hh, kc, vc: stage_fwd(p, hh, kc, vc, ctx_len, d)
    _, vjp = jax.vjp(fn, params, h, k_ctx, v_ctx)
    g_params, g_h, g_kctx, g_vctx = vjp((g_hout, g_knew, g_vnew))
    return (*g_params, g_h, g_kctx, g_vctx)


def embed_bwd(params, tokens, pos_offset, g_h, d: ModelDims):
    fn = lambda p: embed_fwd(p, tokens, pos_offset, d)
    _, vjp = jax.vjp(fn, params)
    (g_params,) = vjp(g_h)
    return g_params


def head_bwd(params, h, targets, d: ModelDims):
    """Returns (g_params…, g_h) for upstream cotangent 1.0 on the loss."""
    fn = lambda p, hh: head_fwd(p, hh, targets, d)
    _, vjp = jax.vjp(fn, params, h)
    g_params, g_h = vjp(jnp.ones((), jnp.float32))
    return (*g_params, g_h)


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def adam_step(params, grads, m, v, step, lr,
              beta1: float = 0.9, beta2: float = 0.999, eps: float = 1e-8):
    """Bias-corrected Adam over a flat tuple of tensors.

    step is the 1-based int32 update counter; lr a float32 scalar. Returns
    (params', m', v') concatenated as one flat tuple (aot donates the
    inputs so the update is in-place at the PJRT level).
    """
    step_f = step.astype(jnp.float32)
    c1 = 1.0 - beta1 ** step_f
    c2 = 1.0 - beta2 ** step_f
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = beta1 * mi + (1.0 - beta1) * g
        vi = beta2 * vi + (1.0 - beta2) * g * g
        p = p - lr * (mi / c1) / (jnp.sqrt(vi / c2) + eps)
        new_p.append(p)
        new_m.append(mi)
        new_v.append(vi)
    return (*new_p, *new_m, *new_v)


# ---------------------------------------------------------------------------
# Whole-model reference (python tests + loss parity with the rust run)
# ---------------------------------------------------------------------------


def full_model_loss(embed, stages, head, tokens, targets, d: ModelDims):
    """Unsliced single-device loss: the oracle for pipelined training.

    Runs the whole model as ONE slice of length L with empty context —
    exercising the very same stage_fwd/head_fwd code the pipeline uses, so
    pipelined-vs-unsliced equality is a pure statement about the schedule.
    """
    b, l = tokens.shape
    h = embed_fwd(embed, tokens, jnp.zeros((), jnp.int32), d)
    empty = jnp.zeros((d.layers_per_stage, b, d.seq_len, d.num_heads, d.head_dim), jnp.float32)
    for sp in stages:
        h, _, _ = stage_fwd(sp, h, empty, empty, jnp.zeros((), jnp.int32), d)
    return head_fwd(head, h, targets, d)


def full_model_grads(embed, stages, head, tokens, targets, d: ModelDims):
    fn = lambda e, ss, hd: full_model_loss(e, ss, hd, tokens, targets, d)
    return jax.grad(fn, argnums=(0, 1, 2))(embed, stages, head)
