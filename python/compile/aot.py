"""AOT compile path: lower every executable to HLO *text* + write manifest.

Python runs exactly once (`make artifacts`); after that the rust binary is
self-contained. For each slice-length bucket S in `--buckets` we lower

  embed_fwd_s{S}, embed_bwd_s{S}   — first pipeline stage only
  stage_fwd_s{S}, stage_bwd_s{S}   — every cell (stages share structure;
                                     parameters are runtime inputs)
  head_fwd_s{S},  head_bwd_s{S}    — last pipeline stage only

plus slice-independent `adam_embed`, `adam_stage`, `adam_head`.

Interchange is HLO TEXT, not `.serialize()`: jax>=0.5 emits HloModuleProto
with 64-bit instruction ids which xla_extension 0.5.1 (what the `xla` 0.1.6
crate links) rejects (`proto.id() <= INT_MAX`); the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Also written:
  artifacts/manifest.json     — model dims, buckets, per-executable input/
                                output names+shapes+dtypes (flat, in HLO
                                parameter order), parameter specs
  artifacts/init/*.bin        — deterministic initial parameters, raw f32
                                little-endian, one file per tensor, so the
                                rust coordinator and the python oracle start
                                from bit-identical weights

Usage: cd python && python -m compile.aot --out-dir ../artifacts [dims…]
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def i32(shape=()):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.int32)


def spec_entry(name, s):
    return {"name": name, "shape": list(s.shape), "dtype": str(s.dtype)}


class Lowerer:
    """Lowers flat-argument functions and records their manifest entries."""

    def __init__(self, d: M.ModelDims, out_dir: str):
        self.d = d
        self.out_dir = out_dir
        self.executables = {}

    def lower(self, name, fn, in_specs, out_names, donate_argnums=()):
        """in_specs: [(name, ShapeDtypeStruct)] in HLO parameter order."""
        args = [s for _, s in in_specs]
        # keep_unused: the rust runtime feeds every manifest input, so the
        # HLO parameter list must match even when a value is algebraically
        # unused (e.g. embed_bwd never reads the embedding tables).
        lowered = jax.jit(
            fn, donate_argnums=donate_argnums, keep_unused=True
        ).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(self.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *args)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        assert len(outs) == len(out_names), (name, len(outs), len(out_names))
        self.executables[name] = {
            "inputs": [spec_entry(n, s) for n, s in in_specs],
            "outputs": [spec_entry(n, s) for n, s in zip(out_names, outs)],
        }
        print(f"  lowered {name}: {len(text)} chars, "
              f"{len(in_specs)} inputs, {len(outs)} outputs")


def build_all(d: M.ModelDims, buckets, out_dir: str, seed: int):
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "init"), exist_ok=True)
    lw = Lowerer(d, out_dir)

    b, t, nh, hd, nl = d.batch, d.seq_len, d.num_heads, d.head_dim, d.layers_per_stage
    stage_specs = M.stage_param_specs(d)
    embed_specs = M.embed_param_specs(d)
    head_specs = M.head_param_specs(d)
    n_sp = len(stage_specs)

    kv_shape = (nl, b, t, nh, hd)

    for s in buckets:
        kv_new = (nl, b, s, nh, hd)

        # ---- embed ----
        def embed_fwd_flat(tok_emb, pos_emb, tokens, pos_offset):
            return M.embed_fwd((tok_emb, pos_emb), tokens, pos_offset, d)

        lw.lower(
            f"embed_fwd_s{s}", embed_fwd_flat,
            [(n, f32(sh)) for n, sh in embed_specs]
            + [("tokens", i32((b, s))), ("pos_offset", i32())],
            ["h"],
        )

        def embed_bwd_flat(tok_emb, pos_emb, tokens, pos_offset, g_h):
            return M.embed_bwd((tok_emb, pos_emb), tokens, pos_offset, g_h, d)

        lw.lower(
            f"embed_bwd_s{s}", embed_bwd_flat,
            [(n, f32(sh)) for n, sh in embed_specs]
            + [("tokens", i32((b, s))), ("pos_offset", i32()), ("g_h", f32((b, s, d.hidden)))],
            [f"g_{n}" for n, _ in embed_specs],
        )

        # ---- stage ----
        def stage_fwd_flat(*args):
            params, (h, kc, vc, cl) = args[:n_sp], args[n_sp:]
            return M.stage_fwd(params, h, kc, vc, cl, d)

        lw.lower(
            f"stage_fwd_s{s}", stage_fwd_flat,
            [(n, f32(sh)) for n, sh in stage_specs]
            + [("h", f32((b, s, d.hidden))), ("k_ctx", f32(kv_shape)),
               ("v_ctx", f32(kv_shape)), ("ctx_len", i32())],
            ["h_out", "k_new", "v_new"],
        )

        def stage_bwd_flat(*args):
            params = args[:n_sp]
            h, kc, vc, cl, g_h, g_k, g_v = args[n_sp:]
            return M.stage_bwd(params, h, kc, vc, cl, g_h, g_k, g_v, d)

        lw.lower(
            f"stage_bwd_s{s}", stage_bwd_flat,
            [(n, f32(sh)) for n, sh in stage_specs]
            + [("h", f32((b, s, d.hidden))), ("k_ctx", f32(kv_shape)),
               ("v_ctx", f32(kv_shape)), ("ctx_len", i32()),
               ("g_hout", f32((b, s, d.hidden))), ("g_knew", f32(kv_new)),
               ("g_vnew", f32(kv_new))],
            [f"g_{n}" for n, _ in stage_specs] + ["g_h", "g_kctx", "g_vctx"],
        )

        # ---- head ----
        def head_fwd_flat(*args):
            params, (h, targets) = args[:4], args[4:]
            return M.head_fwd(params, h, targets, d)

        lw.lower(
            f"head_fwd_s{s}", head_fwd_flat,
            [(n, f32(sh)) for n, sh in head_specs]
            + [("h", f32((b, s, d.hidden))), ("targets", i32((b, s)))],
            ["loss_sum"],
        )

        def head_bwd_flat(*args):
            params, (h, targets) = args[:4], args[4:]
            return M.head_bwd(params, h, targets, d)

        lw.lower(
            f"head_bwd_s{s}", head_bwd_flat,
            [(n, f32(sh)) for n, sh in head_specs]
            + [("h", f32((b, s, d.hidden))), ("targets", i32((b, s)))],
            [f"g_{n}" for n, _ in head_specs] + ["g_h"],
        )

    # ---- optimizers (slice independent). Donate params/m/v so PJRT can
    # update in place. ----
    for group, specs in (("embed", embed_specs), ("stage", stage_specs), ("head", head_specs)):
        n = len(specs)

        def adam_flat(*args, _n=n):
            params, grads = args[:_n], args[_n : 2 * _n]
            m, v = args[2 * _n : 3 * _n], args[3 * _n : 4 * _n]
            step, lr = args[4 * _n], args[4 * _n + 1]
            return M.adam_step(params, grads, m, v, step, lr)

        in_specs = (
            [(nm, f32(sh)) for nm, sh in specs]
            + [(f"g_{nm}", f32(sh)) for nm, sh in specs]
            + [(f"m_{nm}", f32(sh)) for nm, sh in specs]
            + [(f"v_{nm}", f32(sh)) for nm, sh in specs]
            + [("step", i32()), ("lr", f32(()))]
        )
        out_names = (
            [nm for nm, _ in specs]
            + [f"m_{nm}" for nm, _ in specs]
            + [f"v_{nm}" for nm, _ in specs]
        )
        donate = tuple(range(n)) + tuple(range(2 * n, 4 * n))
        lw.lower(f"adam_{group}", adam_flat, in_specs, out_names, donate_argnums=donate)

    # ---- initial parameters ----
    embed, stages, head = M.init_params(d, seed=seed)

    def dump(prefix, names_shapes, arrays):
        files = []
        for (nm, sh), arr in zip(names_shapes, arrays):
            assert tuple(arr.shape) == tuple(sh), (nm, arr.shape, sh)
            fname = f"{prefix}.{nm}.bin"
            np.asarray(arr, dtype="<f4").tofile(os.path.join(out_dir, "init", fname))
            files.append({"name": nm, "shape": list(sh), "file": f"init/{fname}"})
        return files

    init_index = {
        "embed": dump("embed", embed_specs, embed),
        "head": dump("head", head_specs, head),
        "stages": [
            dump(f"stage{k}", stage_specs, stages[k]) for k in range(d.num_stages)
        ],
    }

    manifest = {
        "model": {
            "vocab": d.vocab, "hidden": d.hidden, "num_heads": d.num_heads,
            "layers_per_stage": d.layers_per_stage, "num_stages": d.num_stages,
            "seq_len": d.seq_len, "batch": d.batch, "block_ctx": d.block_ctx,
            "seed": seed,
        },
        "buckets": list(buckets),
        "param_groups": {
            "embed": [{"name": n, "shape": list(sh)} for n, sh in embed_specs],
            "stage": [{"name": n, "shape": list(sh)} for n, sh in stage_specs],
            "head": [{"name": n, "shape": list(sh)} for n, sh in head_specs],
        },
        "init": init_index,
        "executables": lw.executables,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(lw.executables)} executables to {out_dir}")


def parse_args(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--vocab", type=int, default=256)
    p.add_argument("--hidden", type=int, default=128)
    p.add_argument("--heads", type=int, default=4)
    p.add_argument("--layers-per-stage", type=int, default=2)
    p.add_argument("--num-stages", type=int, default=2)
    p.add_argument("--seq-len", type=int, default=128)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--block-ctx", type=int, default=128)
    p.add_argument("--buckets", default="16,32,64,128")
    p.add_argument("--seed", type=int, default=0)
    return p.parse_args(argv)


def main(argv=None):
    a = parse_args(argv)
    d = M.ModelDims(
        vocab=a.vocab, hidden=a.hidden, num_heads=a.heads,
        layers_per_stage=a.layers_per_stage, num_stages=a.num_stages,
        seq_len=a.seq_len, batch=a.batch, block_ctx=a.block_ctx,
    )
    buckets = sorted({int(x) for x in a.buckets.split(",")})
    assert all(bk <= d.seq_len for bk in buckets), "bucket larger than seq_len"
    print(f"lowering {d} buckets={buckets} -> {a.out_dir}")
    build_all(d, buckets, a.out_dir, a.seed)


if __name__ == "__main__":
    main()
