"""L2 correctness: the stage model and — critically — the *pipelined
backward algebra*.

TeraPipe's synchronous-training claim (paper §4: "exactly the same
underlying optimization algorithm") holds only if per-slice backward with
context-gradient accumulation reproduces the full-sequence gradients. The
emulator below mirrors the rust coordinator step for step: forward slices
in order growing the per-stage KV buffers; backward slices in reverse
order, feeding each slice the attention gradients that later slices
deposited on its K/V (`g_knew/g_vnew`) and accumulating the `g_kctx/g_vctx`
it returns. test_pipelined_grads_equal_full_grads is therefore the single
most load-bearing test in the python suite.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

TOL = dict(rtol=5e-4, atol=5e-4)

DIMS = M.ModelDims(
    vocab=64, hidden=64, num_heads=2, layers_per_stage=2, num_stages=2,
    seq_len=32, batch=2, block_ctx=16,
)

# jitted entry points (ModelDims is a NamedTuple of ints → hashable static
# arg); interpret-mode pallas is far too slow to re-trace per call, and jit
# caches by input shapes so repeated slicings are cheap.
j_stage_fwd = jax.jit(M.stage_fwd, static_argnums=(5,))
j_stage_bwd = jax.jit(M.stage_bwd, static_argnums=(8,))
j_embed_fwd = jax.jit(M.embed_fwd, static_argnums=(3,))
j_embed_bwd = jax.jit(M.embed_bwd, static_argnums=(4,))
j_head_fwd = jax.jit(M.head_fwd, static_argnums=(3,))
j_head_bwd = jax.jit(M.head_bwd, static_argnums=(3,))
j_full_loss = jax.jit(M.full_model_loss, static_argnums=(5,))
j_full_grads = jax.jit(M.full_model_grads, static_argnums=(5,))


@pytest.fixture(scope="module")
def params():
    return M.init_params(DIMS, seed=0)


@pytest.fixture(scope="module")
def batch():
    key = jax.random.PRNGKey(7)
    tokens = jax.random.randint(key, (DIMS.batch, DIMS.seq_len), 0, DIMS.vocab)
    targets = jnp.roll(tokens, -1, axis=1)
    return tokens, targets


def empty_kv(d=DIMS):
    return jnp.zeros(
        (d.layers_per_stage, d.batch, d.seq_len, d.num_heads, d.head_dim), jnp.float32
    )


# ---------------------------------------------------------------------------
# Coordinator emulator (python mirror of rust/src/coordinator/)
# ---------------------------------------------------------------------------


def pipelined_loss_and_grads(params, tokens, targets, slice_lens, d=DIMS):
    embed, stages, head = params
    assert sum(slice_lens) == d.seq_len
    K = d.num_stages

    kbuf = [empty_kv(d) for _ in range(K)]
    vbuf = [empty_kv(d) for _ in range(K)]
    h_in = [[] for _ in range(K)]  # per stage, per slice: input activation
    h_out_last = []
    offs = []

    # ---- forward, slice order ----
    off = 0
    for s in slice_lens:
        offs.append(off)
        h = j_embed_fwd(embed, tokens[:, off : off + s], jnp.int32(off), d)
        for k in range(K):
            h_in[k].append(h)
            h, k_new, v_new = j_stage_fwd(stages[k], h, kbuf[k], vbuf[k], jnp.int32(off), d)
            kbuf[k] = jax.lax.dynamic_update_slice(kbuf[k], k_new, (0, 0, off, 0, 0))
            vbuf[k] = jax.lax.dynamic_update_slice(vbuf[k], v_new, (0, 0, off, 0, 0))
        h_out_last.append(h)
        off += s

    loss = sum(
        j_head_fwd(head, h_out_last[i], targets[:, offs[i] : offs[i] + slice_lens[i]], d)
        for i in range(len(slice_lens))
    )

    # ---- backward, reverse slice order ----
    g_embed = [jnp.zeros_like(p) for p in embed]
    g_stages = [[jnp.zeros_like(p) for p in stages[k]] for k in range(K)]
    g_head = [jnp.zeros_like(p) for p in (head)]
    g_kacc = [jnp.zeros_like(empty_kv(d)) for _ in range(K)]
    g_vacc = [jnp.zeros_like(empty_kv(d)) for _ in range(K)]

    for i in reversed(range(len(slice_lens))):
        s, off = slice_lens[i], offs[i]
        *g_hp, g_h = j_head_bwd(head, h_out_last[i], targets[:, off : off + s], d)
        g_head = [a + b for a, b in zip(g_head, g_hp)]
        for k in reversed(range(K)):
            g_know = jax.lax.dynamic_slice(
                g_kacc[k], (0, 0, off, 0, 0),
                (d.layers_per_stage, d.batch, s, d.num_heads, d.head_dim),
            )
            g_vnow = jax.lax.dynamic_slice(
                g_vacc[k], (0, 0, off, 0, 0),
                (d.layers_per_stage, d.batch, s, d.num_heads, d.head_dim),
            )
            out = j_stage_bwd(
                stages[k], h_in[k][i], kbuf[k], vbuf[k], jnp.int32(off),
                g_h, g_know, g_vnow, d,
            )
            n = len(stages[k])
            g_p, g_h, g_kctx, g_vctx = out[:n], out[n], out[n + 1], out[n + 2]
            g_stages[k] = [a + b for a, b in zip(g_stages[k], g_p)]
            g_kacc[k] = g_kacc[k] + g_kctx
            g_vacc[k] = g_vacc[k] + g_vctx
        g_e = j_embed_bwd(embed, tokens[:, off : off + s], jnp.int32(off), g_h, d)
        g_embed = [a + b for a, b in zip(g_embed, g_e)]

    return loss, (g_embed, g_stages, g_head)


SLICINGS = [
    [32],
    [16, 16],
    [8, 8, 8, 8],
    [12, 8, 8, 4],
    [1, 31],
    [31, 1],
    [5, 9, 3, 15],
]


@pytest.mark.parametrize("slice_lens", SLICINGS, ids=[str(s) for s in SLICINGS])
def test_pipelined_loss_equals_full_loss(params, batch, slice_lens):
    tokens, targets = batch
    full = j_full_loss(*params, tokens, targets, DIMS)
    sliced, _ = pipelined_loss_and_grads(params, tokens, targets, slice_lens)
    np.testing.assert_allclose(sliced, full, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("slice_lens", [[16, 16], [12, 8, 8, 4], [1, 31]],
                         ids=["uniform", "nonuniform", "wavefront"])
def test_pipelined_grads_equal_full_grads(params, batch, slice_lens):
    tokens, targets = batch
    embed, stages, head = params
    fg_embed, fg_stages, fg_head = j_full_grads(embed, stages, head, tokens, targets, DIMS)
    _, (g_embed, g_stages, g_head) = pipelined_loss_and_grads(params, tokens, targets, slice_lens)

    for a, b in zip(g_embed, fg_embed):
        np.testing.assert_allclose(a, b, **TOL)
    for k in range(DIMS.num_stages):
        for a, b in zip(g_stages[k], fg_stages[k]):
            np.testing.assert_allclose(a, b, **TOL)
    for a, b in zip(g_head, fg_head):
        np.testing.assert_allclose(a, b, **TOL)


@settings(max_examples=6, deadline=None)
@given(data=st.data())
def test_pipelined_loss_random_slicings(params, batch, data):
    """Any partition of L must give the same loss (paper Fig. 4 freedom).
    Lengths are multiples of 4 to bound the jit compile-cache size."""
    tokens, targets = batch
    rem, lens = DIMS.seq_len, []
    while rem > 0:
        s = 4 * data.draw(st.integers(1, rem // 4))
        lens.append(s)
        rem -= s
    full = j_full_loss(*params, tokens, targets, DIMS)
    sliced, _ = pipelined_loss_and_grads(params, tokens, targets, lens)
    np.testing.assert_allclose(sliced, full, rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# Component-level checks
# ---------------------------------------------------------------------------


def test_stage_fwd_matches_dense_layer_reference(params, batch):
    """stage_fwd over a full-length slice == dense masked attention math."""
    tokens, _ = batch
    embed, stages, _ = params
    d = DIMS
    h = M.embed_fwd(embed, tokens, jnp.int32(0), d)
    out, k_new, v_new = M.stage_fwd(stages[0], h, empty_kv(), empty_kv(), jnp.int32(0), d)

    # independent dense implementation
    x = h
    for i in range(d.layers_per_stage):
        lp = stages[0][i * M.PARAMS_PER_LAYER : (i + 1) * M.PARAMS_PER_LAYER]
        (ln1_g, ln1_b, w_qkv, b_qkv, w_proj, b_proj,
         ln2_g, ln2_b, w_fc1, b_fc1, w_fc2, b_fc2) = lp
        y = M.layer_norm(x, ln1_g, ln1_b)
        qkv = y @ w_qkv + b_qkv
        q, k, v = jnp.split(qkv, 3, axis=-1)
        b_, l_, _ = q.shape
        q = q.reshape(b_, l_, d.num_heads, d.head_dim)
        k = k.reshape(b_, l_, d.num_heads, d.head_dim)
        v = v.reshape(b_, l_, d.num_heads, d.head_dim)
        scores = jnp.einsum("bqnd,bknd->bnqk", q, k) / np.sqrt(d.head_dim)
        mask = jnp.tril(jnp.ones((l_, l_), bool))
        scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        att = jnp.einsum("bnqk,bknd->bqnd", probs, v).reshape(b_, l_, d.hidden)
        x = x + att @ w_proj + b_proj
        y = M.layer_norm(x, ln2_g, ln2_b)
        x = x + M.gelu(y @ w_fc1 + b_fc1) @ w_fc2 + b_fc2
    np.testing.assert_allclose(out, x, rtol=2e-4, atol=2e-4)
    assert k_new.shape == (d.layers_per_stage, d.batch, d.seq_len, d.num_heads, d.head_dim)


def test_head_fwd_matches_manual_xent(params, batch):
    tokens, targets = batch
    _, _, head = params
    d = DIMS
    h = jax.random.normal(jax.random.PRNGKey(1), (d.batch, 8, d.hidden))
    tg = targets[:, :8]
    loss = M.head_fwd(head, h, tg, d)
    lnf_g, lnf_b, w_out, b_out = head
    x = M.layer_norm(h, lnf_g, lnf_b)
    logits = np.asarray(x @ w_out + b_out)
    ref = 0.0
    for b in range(d.batch):
        for t in range(8):
            z = logits[b, t] - logits[b, t].max()
            ref += np.log(np.exp(z).sum()) - z[tg[b, t]]
    np.testing.assert_allclose(loss, ref, rtol=1e-5)


def test_embed_bwd_matches_autograd(params, batch):
    tokens, _ = batch
    embed, _, _ = params
    d = DIMS
    g_h = jax.random.normal(jax.random.PRNGKey(2), (d.batch, 8, d.hidden))
    got = M.embed_bwd(embed, tokens[:, 4:12], jnp.int32(4), g_h, d)
    want = jax.grad(
        lambda e: jnp.sum(M.embed_fwd(e, tokens[:, 4:12], jnp.int32(4), d) * g_h)
    )(embed)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_adam_step_matches_numpy_reference():
    key = jax.random.PRNGKey(0)
    shapes = [(4, 3), (5,), (2, 2, 2)]
    ps = tuple(jax.random.normal(jax.random.fold_in(key, i), s) for i, s in enumerate(shapes))
    gs = tuple(jax.random.normal(jax.random.fold_in(key, 10 + i), s) for i, s in enumerate(shapes))
    ms = tuple(jnp.zeros(s) for s in shapes)
    vs = tuple(jnp.zeros(s) for s in shapes)
    lr, b1, b2, eps = 1e-3, 0.9, 0.999, 1e-8

    out = M.adam_step(ps, gs, ms, vs, jnp.int32(1), jnp.float32(lr))
    n = len(shapes)
    new_p, new_m, new_v = out[:n], out[n : 2 * n], out[2 * n :]
    for p, g, m, v, np_, nm, nv in zip(ps, gs, ms, vs, new_p, new_m, new_v):
        m_ref = b1 * np.asarray(m) + (1 - b1) * np.asarray(g)
        v_ref = b2 * np.asarray(v) + (1 - b2) * np.asarray(g) ** 2
        p_ref = np.asarray(p) - lr * (m_ref / (1 - b1)) / (np.sqrt(v_ref / (1 - b2)) + eps)
        np.testing.assert_allclose(nm, m_ref, rtol=1e-6)
        np.testing.assert_allclose(nv, v_ref, rtol=1e-6)
        np.testing.assert_allclose(np_, p_ref, rtol=1e-5, atol=1e-7)


def test_training_reduces_loss(params, batch):
    """Three full-model Adam steps on one batch must reduce the loss —
    a smoke test that grads point downhill end to end."""
    tokens, targets = batch
    embed, stages, head = params
    d = DIMS

    def loss_fn(e, ss, hd):
        return M.full_model_loss(e, ss, hd, tokens, targets, d)

    flat = (*embed, *[p for sp in stages for p in sp], *head)

    def unflat(flat):
        e = tuple(flat[:2])
        off = 2
        ss = []
        for _ in range(d.num_stages):
            n = len(M.stage_param_specs(d))
            ss.append(tuple(flat[off : off + n]))
            off += n
        return e, ss, tuple(flat[off:])

    m = tuple(jnp.zeros_like(p) for p in flat)
    v = tuple(jnp.zeros_like(p) for p in flat)
    loss0 = loss_fn(*unflat(flat))
    for step in range(3):
        e, ss, hd = unflat(flat)
        ge, gss, ghd = M.full_model_grads(e, ss, hd, tokens, targets, d)
        gflat = (*ge, *[p for sp in gss for p in sp], *ghd)
        out = M.adam_step(flat, gflat, m, v, jnp.int32(step + 1), jnp.float32(1e-2))
        n = len(flat)
        flat, m, v = out[:n], out[n : 2 * n], out[2 * n :]
    loss1 = loss_fn(*unflat(flat))
    assert float(loss1) < float(loss0)


def test_init_params_deterministic():
    a = M.init_params(DIMS, seed=0)
    b = M.init_params(DIMS, seed=0)
    c = M.init_params(DIMS, seed=1)
    np.testing.assert_array_equal(a[0][0], b[0][0])
    assert not np.array_equal(np.asarray(a[0][0]), np.asarray(c[0][0]))


def test_param_specs_cover_init_shapes():
    embed, stages, head = M.init_params(DIMS, seed=0)
    for (n, sh), arr in zip(M.embed_param_specs(DIMS), embed):
        assert tuple(arr.shape) == tuple(sh), n
    for (n, sh), arr in zip(M.stage_param_specs(DIMS), stages[0]):
        assert tuple(arr.shape) == tuple(sh), n
    for (n, sh), arr in zip(M.head_param_specs(DIMS), head):
        assert tuple(arr.shape) == tuple(sh), n
