"""L1 correctness: Pallas slice-attention kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the compute layer: everything the
rust coordinator executes flows through this kernel. hypothesis sweeps the
shape/ctx_len space; fixed cases pin the regressions we care most about
(empty context, full buffer, fully-masked K/V tiles, padding invariance).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import mha_slice_ref, slice_attention_ref
from compile.kernels.slice_attention import (
    mxu_utilization_estimate,
    slice_attention,
    slice_attention_batched,
    vmem_estimate_bytes,
)

TOL = dict(rtol=2e-5, atol=2e-5)


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@pytest.mark.parametrize("ctx_len", [0, 1, 5, 16, 31, 96, 112])
def test_fixed_cases_match_oracle(ctx_len):
    s, t, nh, d = 16, 128, 4, 32
    q, k, v = rand(0, (s, nh, d)), rand(1, (t, nh, d)), rand(2, (t, nh, d))
    out = slice_attention(q, k, v, ctx_len, block_ctx=32)
    ref = mha_slice_ref(q, k, v, ctx_len)
    np.testing.assert_allclose(out, ref, **TOL)


@settings(max_examples=25, deadline=None)
@given(
    s=st.sampled_from([1, 2, 3, 8, 16, 24, 32]),
    t_mult=st.integers(2, 8),
    nh=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([8, 16, 32]),
    block_ctx=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
    data=st.data(),
)
def test_hypothesis_shape_sweep(s, t_mult, nh, d, block_ctx, seed, data):
    t = block_ctx * t_mult
    if s > t:
        s = t
    ctx_len = data.draw(st.integers(0, t - s))
    q, k, v = rand(seed, (s, nh, d)), rand(seed + 1, (t, nh, d)), rand(seed + 2, (t, nh, d))
    out = slice_attention(q, k, v, ctx_len, block_ctx=block_ctx)
    ref = mha_slice_ref(q, k, v, ctx_len)
    np.testing.assert_allclose(out, ref, **TOL)


def test_padding_invariance():
    """Garbage beyond ctx_len + S must not change the output."""
    s, t, nh, d = 8, 64, 2, 16
    q, k, v = rand(0, (s, nh, d)), rand(1, (t, nh, d)), rand(2, (t, nh, d))
    ctx = 16
    out1 = slice_attention(q, k, v, ctx, block_ctx=16)
    k2 = k.at[ctx + s :].set(1e6)
    v2 = v.at[ctx + s :].set(-1e6)
    out2 = slice_attention(q, k2, v2, ctx, block_ctx=16)
    np.testing.assert_allclose(out1, out2, rtol=0, atol=0)


def test_fully_masked_tile_is_exact_zero_contribution():
    """A K/V tile entirely after the causal frontier contributes nothing,
    even when its scores would overflow exp()."""
    s, t, nh, d = 4, 64, 1, 8
    q, k, v = rand(0, (s, nh, d)), rand(1, (t, nh, d)), rand(2, (t, nh, d))
    # ctx_len=0: tiles covering positions >= s are fully masked for all rows
    k = k.at[s:].set(50.0)  # would dominate softmax if leaked
    out = slice_attention(q, k, v, 0, block_ctx=8)
    ref = mha_slice_ref(q, k, v, 0)
    np.testing.assert_allclose(out, ref, **TOL)


def test_single_token_slice():
    """The paper's finest granularity: |s_i| = 1 (wavefront-style)."""
    t, nh, d = 32, 2, 16
    q, k, v = rand(0, (1, nh, d)), rand(1, (t, nh, d)), rand(2, (t, nh, d))
    for ctx in [0, 7, 31]:
        out = slice_attention(q, k, v, ctx, block_ctx=8)
        ref = mha_slice_ref(q, k, v, ctx)
        np.testing.assert_allclose(out, ref, **TOL)


def test_slice_composition_equals_full_attention():
    """Running [0:8) then [8:16) with KV context == one 16-token slice —
    the token-dimension dependency structure the whole paper rests on."""
    t, nh, d = 32, 2, 8
    q, k, v = rand(0, (16, nh, d)), rand(1, (t, nh, d)), rand(2, (t, nh, d))
    full = slice_attention(q, k, v, 0, block_ctx=8)
    part1 = slice_attention(q[:8], k, v, 0, block_ctx=8)
    # Second slice: its own K/V already sit at positions [8, 16) in the
    # buffer (the coordinator's scatter), context is the first 8.
    part2 = slice_attention(q[8:], k, v, 8, block_ctx=8)
    np.testing.assert_allclose(jnp.concatenate([part1, part2]), full, **TOL)


def test_batched_matches_per_sequence():
    b, s, t, nh, d = 3, 8, 32, 2, 16
    q, k, v = rand(0, (b, s, nh, d)), rand(1, (b, t, nh, d)), rand(2, (b, t, nh, d))
    out = slice_attention_batched(q, k, v, 4, block_ctx=16)
    for i in range(b):
        np.testing.assert_allclose(out[i], mha_slice_ref(q[i], k[i], v[i], 4), **TOL)


def test_grad_matches_oracle_grad():
    s, t, nh, d = 8, 32, 2, 16
    q, k, v = rand(0, (s, nh, d)), rand(1, (t, nh, d)), rand(2, (t, nh, d))
    w = rand(3, (s, nh, d))

    def f_kernel(q, k, v):
        return jnp.sum(slice_attention(q, k, v, 4, block_ctx=16) * w)

    def f_ref(q, k, v):
        return jnp.sum(mha_slice_ref(q, k, v, 4) * w)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, **TOL)


def test_traced_ctx_len_under_jit():
    """ctx_len must be a runtime operand (the AOT executables rely on it)."""
    s, t, nh, d = 8, 32, 2, 16
    q, k, v = rand(0, (s, nh, d)), rand(1, (t, nh, d)), rand(2, (t, nh, d))
    f = jax.jit(lambda c: slice_attention(q, k, v, c, block_ctx=16))
    for ctx in [0, 4, 24]:
        np.testing.assert_allclose(f(jnp.int32(ctx)), mha_slice_ref(q, k, v, ctx), **TOL)


def test_indivisible_block_raises():
    q, k, v = rand(0, (4, 1, 8)), rand(1, (24, 1, 8)), rand(2, (24, 1, 8))
    with pytest.raises(ValueError, match="not divisible"):
        slice_attention(q, k, v, 0, block_ctx=16)


def test_single_head_2d_oracle_agrees_with_mha_oracle():
    """ref-vs-ref sanity: the two oracle entry points agree."""
    s, t, d = 8, 32, 16
    q, k, v = rand(0, (s, 1, d)), rand(1, (t, 1, d)), rand(2, (t, 1, d))
    a = mha_slice_ref(q, k, v, 4)[:, 0, :]
    b = slice_attention_ref(q[:, 0, :], k[:, 0, :], v[:, 0, :], 4)
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_vmem_estimate_scales_with_block_not_buffer():
    """Flash structure: VMEM must be O(S·block_ctx), not O(S·T)."""
    small = vmem_estimate_bytes(128, 64, 64)
    # 16x longer buffer, same tile: footprint unchanged by construction
    assert vmem_estimate_bytes(128, 64, 64) == small
    assert vmem_estimate_bytes(128, 64, 128) > small
    assert 0.0 < mxu_utilization_estimate(128, 64, 64) <= 1.0
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
