"""AOT path checks: manifest ↔ HLO ↔ init-file consistency, and a numeric
round-trip of a lowered executable through the same xla_client the rust
side's PJRT CPU client wraps (compile HLO text → execute → compare with the
live-jax result). These guard the interchange contract the rust runtime
depends on.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def dims(manifest):
    m = manifest["model"]
    return M.ModelDims(
        vocab=m["vocab"], hidden=m["hidden"], num_heads=m["num_heads"],
        layers_per_stage=m["layers_per_stage"], num_stages=m["num_stages"],
        seq_len=m["seq_len"], batch=m["batch"], block_ctx=m["block_ctx"],
    )


def test_all_expected_executables_present(manifest):
    buckets = manifest["buckets"]
    names = set(manifest["executables"])
    for s in buckets:
        for role in ("embed_fwd", "embed_bwd", "stage_fwd", "stage_bwd",
                     "head_fwd", "head_bwd"):
            assert f"{role}_s{s}" in names
    for g in ("embed", "stage", "head"):
        assert f"adam_{g}" in names


def test_hlo_files_exist_and_parse_shape(manifest):
    for name, spec in manifest["executables"].items():
        path = os.path.join(ART, f"{name}.hlo.txt")
        assert os.path.exists(path), name
        text = open(path).read()
        assert "ENTRY" in text, name
        # one HLO parameter per manifest input — count inside the ENTRY
        # computation only (nested computations have their own parameters)
        entry = text.split("ENTRY", 1)[1]
        n_params = entry.count("parameter(")
        assert n_params == len(spec["inputs"]), (name, n_params, len(spec["inputs"]))


def test_init_files_match_declared_shapes(manifest):
    groups = [manifest["init"]["embed"], manifest["init"]["head"]]
    groups += manifest["init"]["stages"]
    for group in groups:
        for entry in group:
            path = os.path.join(ART, entry["file"])
            n = int(np.prod(entry["shape"])) if entry["shape"] else 1
            assert os.path.getsize(path) == 4 * n, entry["file"]


def test_init_files_reproduce_init_params(manifest, dims):
    embed, stages, head = M.init_params(dims, seed=manifest["model"]["seed"])
    tok = np.fromfile(
        os.path.join(ART, manifest["init"]["embed"][0]["file"]), dtype="<f4"
    ).reshape(dims.vocab, dims.hidden)
    np.testing.assert_array_equal(tok, np.asarray(embed[0]))
    s0 = manifest["init"]["stages"][0]
    w_qkv = np.fromfile(os.path.join(ART, s0[2]["file"]), dtype="<f4").reshape(
        dims.hidden, 3 * dims.hidden
    )
    np.testing.assert_array_equal(w_qkv, np.asarray(stages[0][2]))


def test_stage_param_count_matches_manifest(manifest, dims):
    specs = manifest["param_groups"]["stage"]
    assert len(specs) == dims.layers_per_stage * M.PARAMS_PER_LAYER
    want = M.stage_param_specs(dims)
    for got, (name, shape) in zip(specs, want):
        assert got["name"] == name and tuple(got["shape"]) == tuple(shape)


# NOTE: the full numeric roundtrip (HLO text → PJRT compile → execute →
# compare against live jax) runs on the *rust* side, where it matters:
# rust/tests/pipeline_integration.rs::slice_composition_matches_full_forward.
# Here we verify the textual contract the rust loader depends on: the HLO
# parses and its ENTRY signature matches the manifest exactly.

import re


def _entry_signature(name):
    """Parse the `entry_computation_layout={(…)->(…)}` header."""
    text = open(os.path.join(ART, f"{name}.hlo.txt")).read()
    # sanity: jaxlib's own parser accepts it
    xc._xla.hlo_module_from_text(text)
    # greedy: layout annotations like {2,1,0} contain braces, so anchor on
    # the single ')->(' separator and the trailing ')}'
    m = re.search(r"entry_computation_layout=\{\((?P<params>.*)\)->\((?P<res>.*)\)\}", text)
    assert m, f"no entry_computation_layout in {name}"

    def shapes(segment):
        out = []
        for dtype, dims_s in re.findall(r"(\w+)\[([\d,]*)\]", segment):
            dims = [int(x) for x in dims_s.split(",") if x] if dims_s else []
            out.append((dtype, dims))
        return out

    return shapes(m.group("params")), shapes(m.group("res"))


DTYPE = {"float32": "f32", "int32": "s32"}


@pytest.mark.parametrize("role", ["head_fwd", "stage_fwd", "stage_bwd", "embed_fwd"])
def test_entry_signature_matches_manifest(manifest, role):
    s = manifest["buckets"][0]
    name = f"{role}_s{s}"
    spec = manifest["executables"][name]
    params, res = _entry_signature(name)
    assert len(params) == len(spec["inputs"]), name
    for (dtype, dims), want in zip(params, spec["inputs"]):
        assert dims == want["shape"], (name, want["name"])
        assert dtype == DTYPE[want["dtype"]], (name, want["name"])
    assert len(res) == len(spec["outputs"]), name
    for (dtype, dims), want in zip(res, spec["outputs"]):
        assert dims == want["shape"], (name, want["name"])


def test_adam_signature_matches_manifest(manifest):
    spec = manifest["executables"]["adam_stage"]
    params, res = _entry_signature("adam_stage")
    assert len(params) == len(spec["inputs"])
    assert len(res) == len(spec["outputs"])
    # 4n + 2 inputs, 3n outputs
    n = (len(params) - 2) // 4
    assert len(res) == 3 * n


def test_lowerer_records_io_in_order(tmp_path, dims):
    lw = aot.Lowerer(dims, str(tmp_path))
    lw.lower(
        "toy", lambda a, b: (a + b, a * b),
        [("a", aot.f32((2, 2))), ("b", aot.f32((2, 2)))],
        ["sum", "prod"],
    )
    spec = lw.executables["toy"]
    assert [i["name"] for i in spec["inputs"]] == ["a", "b"]
    assert [o["name"] for o in spec["outputs"]] == ["sum", "prod"]
    assert (tmp_path / "toy.hlo.txt").exists()
