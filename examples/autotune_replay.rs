//! The online planner service, driven as a library: a long-lived
//! [`Planner`] absorbing a cluster-event stream — the programmatic twin
//! of `terapipe autotune`.
//!
//! ```bash
//! cargo run --release --example autotune_replay
//! ```
//!
//! Walks the full loop: cold initial solve, warm re-solves on a topology
//! change and a bandwidth degradation (cost-table cache serving rescales
//! from the densified diagonals), drift detected from sampled latencies
//! the planner was never told about, hysteresis deciding each switch —
//! and every emitted plan replayed through the discrete-event simulator
//! to confirm its predicted Eq. 5 latency.

use terapipe::config::presets;
use terapipe::perfmodel::analytic::AnalyticModel;
use terapipe::perfmodel::{CostModel, ScaledModel};
use terapipe::planner::drift::LatencySample;
use terapipe::planner::{validate, Planner, PlannerConfig, ReplanDecision};
use terapipe::util::Rng;

fn show(p: &Planner<AnalyticModel>, what: &str, d: &ReplanDecision) {
    let sim = validate::validate_scheme(&p.current_model(), &d.scheme, d.stages, 1e-9)
        .expect("planner predictions replay exactly");
    println!(
        "{what}: K={} Eq.5 {:.3} ms (sim confirms {:.3}), gain {:+.2}% -> {}",
        d.stages,
        d.scheme.latency_ms,
        sim,
        100.0 * d.gain_rel,
        if d.switched { "switched" } else { "kept active plan" }
    );
    if let Some(w) = d.warm {
        println!(
            "    warm: boundary at candidate {} after {} probes (window {})",
            w.boundary,
            w.probes,
            if w.hit { "hit" } else { "miss" }
        );
    }
}

fn main() {
    // GPT3-44B, 48 stages (Table 1 row 8) — the deep-pipeline regime
    // where plan choice is most sensitive to cluster shape.
    let setting = presets::setting(8);
    let k = setting.parallel.pipeline_stages;
    let l = setting.model.seq_len;
    let base = AnalyticModel::from_setting(&setting, 1);
    let gran = 32;
    let mut planner = Planner::new(
        "analytic/setting8",
        base,
        l,
        k,
        PlannerConfig { granularity: gran, eps_ms: 0.1, ..Default::default() },
    );

    println!("=== initial cold solve ===");
    let first = planner.plan().clone();
    let sim = validate::validate_scheme(&planner.current_model(), &first, k, 1e-9).unwrap();
    println!("K={k} Eq.5 {:.3} ms (sim confirms {sim:.3}): {}", first.latency_ms, first.notation());

    println!("\n=== cluster events ===");
    let d = planner.on_stages_change(k / 2);
    show(&planner, "half the nodes leave (K -> K/2)", &d);
    let d = planner.on_bandwidth_change(0.5);
    show(&planner, "inter-node bandwidth halves", &d);
    let d = planner.on_stages_change(k);
    show(&planner, "nodes rejoin (K restored)", &d);

    println!("\n=== undisclosed 30% slowdown, surfaced via samples ===");
    let (compute, comm) = planner.scales();
    let truth = ScaledModel { inner: AnalyticModel::from_setting(&setting, 1), compute, comm };
    let mut rng = Rng::new(7);
    let max_units = l / gran;
    let mut fed = 0;
    loop {
        let iu = 1 + rng.below(max_units.min(8));
        let ju = rng.below(max_units - iu + 1);
        let (i, j) = (iu * gran, ju * gran);
        let ms = 1.3 * (truth.t(i, j) + truth.t_comm(i));
        fed += 1;
        if let Some(d) = planner.on_sample(LatencySample { i, j, ms }) {
            println!(
                "drift detected after {fed} samples (fitted compute scale {:.3})",
                planner.scales().0
            );
            show(&planner, "drift replan", &d);
            break;
        }
    }

    let cs = planner.cache_stats();
    println!(
        "\ncost-table cache: {} densifications, {} rescales, {} hits over {} solves",
        cs.base_misses,
        cs.rescales,
        cs.base_hits + cs.scaled_hits,
        5
    );
}
