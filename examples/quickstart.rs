//! Quickstart: solve a TeraPipe slicing for a paper setting and inspect
//! the schedule.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the core API end to end: pick a Table 1 setting, build the
//! analytic cost model, run the §3.3 token DP and the §3.4 joint
//! batch+token DP, then execute both the GPipe baseline and the TeraPipe
//! plan on the discrete-event simulator and print the timelines.

use terapipe::config::presets;
use terapipe::experiments::AnalyticPhase;
use terapipe::perfmodel::analytic::AnalyticModel;
use terapipe::sim::engine::simulate;
use terapipe::sim::schedule::build_plan;
use terapipe::sim::trace;
use terapipe::solver::dp::solve_tokens;
use terapipe::solver::joint::{gpipe_plan, solve_joint_analytic, JointOpts};

fn main() {
    // 1. A paper setting: GPT3-44B on 384 GPUs, 48 pipeline stages (row 8).
    let setting = presets::setting(8);
    let k = setting.parallel.pipeline_stages;
    let l = setting.model.seq_len;
    let b = setting.batch_per_pipeline();
    println!(
        "setting (8): {} — K={k} stages, L={l}, {} sequences/pipeline\n",
        setting.model.name, b
    );

    // 2. Cost model (Eq. 4/9): per-cell slice latency t(i, j).
    let model = AnalyticModel::from_setting(&setting, 1);

    // 3. Token-dimension DP (Algorithm 1 + t_max enumeration, §3.3),
    // running on the parallel anti-diagonal engine.
    let ((scheme, stats), dp_ms) = terapipe::util::time_ms(|| solve_tokens(&model, l, k, 16, 0.1));
    println!("single-sequence DP scheme: {}", scheme.notation());
    println!(
        "  Eq.5 latency {:.1} ms ({} slices; {} t_max candidates, {} DPs after pruning + {} feasibility probes; solved in {dp_ms:.1} ms)\n",
        scheme.latency_ms,
        scheme.num_slices(),
        stats.candidates,
        stats.dps_run,
        stats.probe_dps
    );

    // 4. Joint batch+token plan (§3.4) vs the GPipe baseline.
    let opts = JointOpts { granularity: 16, eps_ms: 0.1, max_microbatch: Some(8) };
    let tera = solve_joint_analytic(&model, b, l, k, &opts);
    let gpipe = gpipe_plan(&|m| model.with_microbatch(m), b, l, k);
    println!("TeraPipe plan: {}", tera.notation());
    println!("GPipe baseline: {}\n", gpipe.notation());

    // 5. Execute both schedules on the discrete-event simulator.
    let cost = AnalyticPhase { base: &model };
    let g = simulate(&build_plan(&cost, &gpipe, k as usize, None, true)).unwrap();
    let t = simulate(&build_plan(&cost, &tera, k as usize, None, true)).unwrap();
    println!(
        "GPipe:    {:>8.1} ms/iter, {:>4.1}% bubbles",
        g.makespan_ms,
        100.0 * g.bubble_fraction
    );
    println!(
        "TeraPipe: {:>8.1} ms/iter, {:>4.1}% bubbles  →  {:.2}x speedup",
        t.makespan_ms,
        100.0 * t.bubble_fraction,
        g.makespan_ms / t.makespan_ms
    );

    // 6. Fig. 2-style timeline of the first stages (token slices visibly
    // overlapping across stages).
    println!("\nTeraPipe timeline (stages 0–7 of {k}):");
    let spans: Vec<_> = t.trace.iter().filter(|s| s.stage < 8).cloned().collect();
    print!("{}", trace::ascii(&spans, 8, 100));
}
