//! Fig. 7 scenario as a standalone study: how the token dimension rescues
//! pipeline efficiency as sequences grow and memory forces tiny batches
//! (the workload the paper's §4.3 argues will dominate future LMs).
//!
//! ```bash
//! cargo run --release --example long_sequence -- [max_seq_len]
//! ```
//!
//! For each L ∈ {2048, 4096, 6144, 8192(, …)} this derives the paper's
//! memory-constrained batch size from the analytic memory model, solves
//! the joint DP, and compares against GPipe — also showing the bubble
//! fraction, which is the mechanism behind the speedup.

use terapipe::config::presets;
use terapipe::experiments::{sim_iteration_ms, AnalyticPhase};
use terapipe::perfmodel::analytic::AnalyticModel;
use terapipe::solver::joint::{gpipe_plan, solve_joint_analytic, JointOpts};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let max_l: u32 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8192);

    let opts = JointOpts {
        granularity: 16,
        eps_ms: 0.1,
        max_microbatch: Some(4),
    };
    println!("# Long-sequence study — GPT3-13B, 40-stage pipeline (setting 5)");
    println!("| L | B (mem-limited) | GPipe s | GPipe bubbles | TeraPipe s | TeraPipe bubbles | speedup |");

    for (seq_len, batch) in [(2048u32, 32u32), (4096, 8), (6144, 4), (8192, 2), (16384, 1)] {
        if seq_len > max_l {
            break;
        }
        let mut setting = presets::setting(5);
        setting.model.seq_len = seq_len;
        setting.parallel.batch_size = batch;

        let base = AnalyticModel::from_setting(&setting, 1);
        let k = setting.parallel.pipeline_stages;
        let b = setting.batch_per_pipeline();

        let gpipe = gpipe_plan(&|m| base.with_microbatch(m), b, seq_len, k);
        // the parallel engine keeps even the L=16384 solve interactive
        let (tera, solve_ms) =
            terapipe::util::time_ms(|| solve_joint_analytic(&base, b, seq_len, k, &opts));
        eprintln!("  [L={seq_len}] joint DP solved in {solve_ms:.0} ms");

        let g = sim_iteration_ms(&setting, &gpipe);
        let t = sim_iteration_ms(&setting, &tera);
        let _ = AnalyticPhase { base: &base }; // (phase splitter used inside sim_iteration_ms)
        println!(
            "| {seq_len} | {batch} | {:.3} | {:>4.1}% | {:.3} | {:>4.1}% | {:.2}x |",
            g.makespan_ms / 1e3,
            100.0 * g.bubble_fraction,
            t.makespan_ms / 1e3,
            100.0 * t.bubble_fraction,
            g.makespan_ms / t.makespan_ms
        );
    }
    println!("\npaper (Fig. 7): 1.40x @2048, 2.76x @4096, 4.97x @6144, 7.83x @8192 —");
    println!("the reproduced claim is the monotone growth of the token-dimension win.");
}
