//! Reproduce the paper's full evaluation sweep in one run: Fig. 3, Fig. 5
//! (all Table 1 settings), Fig. 6 ablations, Fig. 7 sequence-length sweep
//! and the Appendix A memory study — printed as markdown tables with the
//! paper's published numbers alongside.
//!
//! ```bash
//! cargo run --release --example paper_sweep
//! ```

use terapipe::config::presets;
use terapipe::experiments as exp;
use terapipe::solver::joint::JointOpts;

fn main() {
    let t0 = std::time::Instant::now();
    let opts = JointOpts {
        granularity: 16,
        eps_ms: 0.1,
        max_microbatch: Some(8),
    };

    println!("# TeraPipe evaluation sweep (simulated 48×p3.16xlarge testbed)\n");

    println!("## Fig. 3 — GPT3-1B single-layer fwd curve (analytic V100)");
    println!("| tokens | fwd ms | tokens/ms |");
    for (t, ms, tp) in exp::fig3_curve(&presets::gpt3_1b(), 2048) {
        println!("| {t} | {ms:.3} | {tp:.1} |");
    }

    println!("\n## Fig. 5 / Table 2 — all ten settings");
    let rows = exp::fig5_all(&opts);
    print!("{}", exp::render_fig5(&rows));

    for (setting, max_slices) in [(8u32, 16u32), (9, 128)] {
        println!("\n## Fig. 6 — uniform vs DP, setting ({setting})");
        println!("| algorithm | latency (s) | TFLOPs/GPU |");
        for (label, _, lat, tf) in exp::fig6_rows(setting, max_slices, &opts) {
            println!("| {label} | {lat:.3} | {tf:.4} |");
        }
    }

    println!("\n## Fig. 7 / Table 4 — sequence length sweep (GPT3-13B, setting 5)");
    println!("| L | w/o (s) | w/ (s) | speedup | paper |");
    let paper = [1.40, 2.76, 4.97, 7.83];
    for ((l, g, t, sp, _), p) in exp::fig7_rows(&opts).into_iter().zip(paper) {
        println!("| {l} | {g:.3} | {t:.3} | {sp:.2}x | {p:.2}x |");
    }

    println!("\n## Appendix A — memory-capped pipelines");
    println!("| schedule | makespan |");
    for (label, ms) in exp::appendix_a_rows() {
        println!("| {label} | {ms:.1} |");
    }

    println!(
        "\n(full sweep solved + simulated in {:.1}s on {} threads)",
        t0.elapsed().as_secs_f64(),
        rayon::current_num_threads()
    );
}
