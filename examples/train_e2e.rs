//! End-to-end validation driver (system-prompt mandate): train a small
//! GPT across real pipeline stages — AOT JAX/Pallas executables under a
//! threaded rust PJRT coordinator — on a synthetic corpus, and log the
//! loss curve. Recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_e2e -- [steps]
//! ```
//!
//! Every step is a full synchronous update: token slices pipelined
//! forward, context-gradient-accumulated backward, Adam on every stage.
//! The run also demonstrates TeraPipe's correctness claim live: we train
//! the same model twice — unsliced vs DP-sliced — and print both curves
//! (they match to fp32 noise).

use std::path::PathBuf;

use terapipe::coordinator::{train, TrainConfig};
use terapipe::data::synthetic_corpus;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not built: run `make artifacts` first");
        std::process::exit(1);
    }
    let corpus = synthetic_corpus(1 << 16, 7);

    let run = |label: &str, slicing: Vec<usize>| -> Vec<f64> {
        println!("\n=== {label}: slicing {slicing:?}, {steps} steps ===");
        let cfg = TrainConfig {
            slicing,
            steps,
            seed: 42,
            ..Default::default()
        };
        let reports = train(&dir, cfg, &corpus, |r| {
            if r.step < 3 || r.step % 20 == 0 || r.step == steps - 1 {
                println!(
                    "step {:>4}  loss {:.4}  {:>7.1} ms  {:>6.0} tok/s",
                    r.step,
                    r.loss,
                    r.wall_ms,
                    r.tokens as f64 / (r.wall_ms / 1e3)
                );
            }
        })
        .expect("training failed");
        reports.iter().map(|r| r.loss).collect()
    };

    // TeraPipe token-sliced training (front-loaded DP-style scheme).
    let sliced = run("TeraPipe (token slices)", vec![64, 32, 16, 16]);
    // Unsliced baseline — same math, bubblier schedule.
    let unsliced = run("unsliced baseline", vec![128]);

    println!("\n=== synchronous-equivalence check (paper §4) ===");
    let mut max_diff = 0f64;
    for (a, b) in sliced.iter().zip(&unsliced) {
        max_diff = max_diff.max((a - b).abs());
    }
    println!(
        "max per-step loss difference sliced-vs-unsliced: {max_diff:.2e} {}",
        if max_diff < 5e-3 {
            "(identical training dynamics ✓)"
        } else {
            "(UNEXPECTED divergence!)"
        }
    );
    println!(
        "loss curve: {:.4} -> {:.4} over {} steps (byte-level LM, ln(256)≈5.55 at init)",
        sliced.first().unwrap(),
        sliced.last().unwrap(),
        sliced.len()
    );
}
